"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,...,derived`` CSV lines per experiment (see DESIGN.md §10 for
the table-to-code index) and a final summary. The dry-run / roofline tables
(EXPERIMENTS.md §Dry-run/§Roofline) are produced by their own modules
(repro.launch.dryrun, benchmarks.roofline) since they need the
512-placeholder-device interpreter.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="skip the trained-model PPL section (slowest)")
    args = p.parse_args(argv)

    t0 = time.time()
    failures = []

    def section(title):
        print(f"\n===== {title} =====", flush=True)

    section("Table 4 analogue: kernel optimization ablation (v5e model)")
    from benchmarks import bench_kernel_ablation

    r = bench_kernel_ablation.run()
    if r["total_speedup"] < 2:
        failures.append("kernel_ablation")

    section("Fig. 5 / Tables 13-14 analogue: GEMM/GEMV throughput model")
    from benchmarks import bench_gemm_bytes

    r = bench_gemm_bytes.run()
    if r["kernel_check_err"] > 1e-3:
        failures.append("gemm_kernel_check")

    section("Fig. 6 / Table 12 analogue: end-to-end memory & decode latency")
    from benchmarks import bench_e2e_memory

    r = bench_e2e_memory.run()
    if not (r["ratio_fp16"] > 3.0 and r["ratio_w8a8"] > 1.8):
        failures.append("e2e_memory")

    section("Fused decode fast-path: ReQuant+GEMM bytes/token & tok/s")
    from benchmarks import bench_decode

    r = bench_decode.run(smoke=not args.fast)
    if not r["fused_strictly_fewer_bytes"]:
        failures.append("decode_fused_bytes")

    if not args.fast:
        section("Tables 1/2/5/6/7 analogue: quantization-config perplexity"
                " (trains the benchmark LM on first run)")
        from benchmarks import bench_quant_ppl

        r = bench_quant_ppl.run()
        for name, ok in r["checks"].items():
            if not ok:
                failures.append(f"quant_ppl:{name}")

    section("summary")
    print(f"benchmarks completed in {time.time()-t0:.0f}s; "
          f"{'ALL CHECKS PASS' if not failures else 'FAILURES: ' + str(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

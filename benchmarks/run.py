"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,...,derived`` CSV lines per experiment (see DESIGN.md §10 for
the table-to-code index) and a final summary. The dry-run / roofline tables
(EXPERIMENTS.md §Dry-run/§Roofline) are produced by their own modules
(repro.launch.dryrun, benchmarks.roofline) since they need the
512-placeholder-device interpreter.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time


def decode_byte_sections(smoke: bool, section=None) -> list[str]:
    """The decode fast-path byte gates, shared by the full run and --check:
    fused ReQuant+GEMM and Pallas decode-attention must model strictly
    fewer HBM bytes than their baselines (plus, with ``smoke``, the
    decode-attention tok/s non-regression check). Smoke-less runs write to
    a scratch dir so the tracked BENCH_*.json (which carry the smoke tok/s
    history) are never clobbered."""
    from benchmarks import bench_decode, bench_decode_attn, bench_prefill_chunk

    if smoke:
        bench_dir = ""
    else:
        import tempfile

        bench_dir = tempfile.mkdtemp(prefix="repro_bench_bytes_") + "/"
    section = section or (lambda title: None)
    failures = []

    section("Fused decode fast-path: ReQuant+GEMM bytes/token & tok/s")
    r = bench_decode.run(smoke=smoke,
                         out_path=f"{bench_dir}BENCH_decode.json")
    if not r["fused_strictly_fewer_bytes"]:
        failures.append("decode_fused_bytes")

    section("Decode-attention fast-path: flash-decoding cache bytes/token")
    r = bench_decode_attn.run(smoke=smoke,
                              out_path=f"{bench_dir}BENCH_decode_attn.json")
    if not r["pallas_strictly_fewer_bytes"]:
        failures.append("decode_attn_pallas_bytes")
    if not r.get("smoke_not_regressed", True):
        failures.append("decode_attn_smoke")

    section("Chunked-prefill attention: prefix-clamped cache bytes/chunk")
    r = bench_prefill_chunk.run(
        smoke=smoke, out_path=f"{bench_dir}BENCH_prefill_chunk.json")
    if not r["prefix_scaling_ok"]:
        failures.append("prefill_chunk_bytes")
    if not r.get("smoke_not_regressed", True):
        failures.append("prefill_chunk_smoke")
    return failures


def serving_section(smoke: bool, section=None) -> list[str]:
    """Continuous-batching regression gates, shared by the full run and
    --check: the engine must model >= 1.5x static-batcher throughput on
    the Poisson workload (slot-step account; deterministic), paged KV
    allocation must admit strictly more concurrent short requests than
    slot rows under the same cache budget (admission account;
    deterministic), and with ``smoke`` the engine must hit >= 1.5x
    wall-clock on the tiny model and the real paged engine must beat the
    real slot engine's peak concurrency — with bitwise-matching outputs
    off-TPU (on TPU the two paths pick different attention tile sizes,
    so only the concurrency half gates; see bench_serving). The overcommit
    gates (optimistic admission >= 1.3x the worst-case-reservation
    baseline's modeled peak concurrency; preempt-and-requeue bitwise
    invisible in a real churning engine's outputs) and the telemetry
    gates (metrics-on bitwise-equal and within tolerance of metrics-off;
    snapshot schema stable) run smoke or not, so --check catches
    instrumentation regressions too.
    Smoke-less runs write to scratch (tracked BENCH_serving.json keeps its
    smoke history)."""
    from benchmarks import bench_serving

    if smoke:
        bench_dir = ""
    else:
        import tempfile

        bench_dir = tempfile.mkdtemp(prefix="repro_bench_serving_") + "/"
    section = section or (lambda title: None)
    failures = []

    section("Continuous batching: engine vs static batcher (Poisson arrivals)")
    r = bench_serving.run(smoke=smoke,
                          out_path=f"{bench_dir}BENCH_serving.json")
    if not r["modeled_speedup_ok"]:
        failures.append("serving_modeled_speedup")
    if not r["paged_concurrency_ok"]:
        failures.append("serving_paged_concurrency")
    # wall-clock gate is slacked (CPU noise) — the modeled gate above is
    # the deterministic one; the >= 1.5x smoke claim lives in the artifact
    if smoke and not r.get("smoke_not_regressed", True):
        failures.append("serving_smoke_regressed")
    # the paged smoke gate is step-count-deterministic (peak concurrency,
    # plus bitwise outputs off-TPU), so no wall-clock slack applies
    if smoke and not r.get("paged_smoke_ok", True):
        failures.append("serving_paged_smoke")
    # chunked prefill over the paged pool: long prompts must flow through
    # the chunked path with outputs matching the slot-row chunked engine
    # and the one-shot engine (deterministic token equality, off-TPU)
    if smoke and not r.get("chunked_paged_ok", True):
        failures.append("serving_chunked_paged")
    # overcommit gates run smoke or not (deterministic): optimistic
    # admission must model >= 1.3x the worst-case-reservation baseline's
    # peak concurrency on the heavy-tailed workload, and a churning
    # overcommit engine must emit bitwise the same streams as a no-churn
    # sequential run — preempt-and-requeue must be invisible in outputs
    # (see bench_serving §5)
    if not r.get("overcommit_concurrency_ok", True):
        failures.append("serving_overcommit_concurrency")
    if not r.get("preempt_exactness_ok", True):
        failures.append("serving_preempt_exactness")
    # telemetry gates run smoke or not: metrics-on must produce bitwise
    # outputs and stay within tolerance of metrics-off wall-clock, and the
    # operator snapshot must keep its schema (see bench_serving §6)
    if not r.get("metrics_overhead_ok", True):
        failures.append("serving_metrics_overhead")
    if not r.get("metrics_schema_ok", True):
        failures.append("serving_metrics_schema")
    # fault chaos runs smoke or not (seeded, deterministic): injected
    # pool exhaustion / NaN logits / clock jumps / storms / cancels must
    # leave zero invariant violations — pool conservation, every request
    # terminal, metrics terminal-reason conservation (see bench_serving §7)
    if not r.get("fault_chaos_ok", True):
        failures.append("serving_fault_chaos")
    return failures


def check_bytes() -> int:
    """CI gate (--check): exits nonzero on any byte/slot-step-model
    regression."""
    failures = decode_byte_sections(smoke=False) + serving_section(smoke=False)
    print(f"byte-model check: "
          f"{'ALL PASS' if not failures else 'FAILURES: ' + str(failures)}")
    return 1 if failures else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="skip the trained-model PPL section (slowest)")
    p.add_argument("--check", action="store_true",
                   help="byte-model regression gate only: exit nonzero if a "
                        "fused/pallas mode stops being strictly-fewer-bytes "
                        "than its baseline")
    args = p.parse_args(argv)

    if args.check:
        return check_bytes()

    t0 = time.time()
    failures = []

    def section(title):
        print(f"\n===== {title} =====", flush=True)

    section("Table 4 analogue: kernel optimization ablation (v5e model)")
    from benchmarks import bench_kernel_ablation

    r = bench_kernel_ablation.run()
    if r["total_speedup"] < 2:
        failures.append("kernel_ablation")

    section("Fig. 5 / Tables 13-14 analogue: GEMM/GEMV throughput model")
    from benchmarks import bench_gemm_bytes

    r = bench_gemm_bytes.run()
    if r["kernel_check_err"] > 1e-3:
        failures.append("gemm_kernel_check")

    section("Fig. 6 / Table 12 analogue: end-to-end memory & decode latency")
    from benchmarks import bench_e2e_memory

    r = bench_e2e_memory.run()
    if not (r["ratio_fp16"] > 3.0 and r["ratio_w8a8"] > 1.8):
        failures.append("e2e_memory")

    failures += decode_byte_sections(smoke=not args.fast, section=section)
    failures += serving_section(smoke=not args.fast, section=section)

    if not args.fast:
        section("Tables 1/2/5/6/7 analogue: quantization-config perplexity"
                " (trains the benchmark LM on first run)")
        from benchmarks import bench_quant_ppl

        r = bench_quant_ppl.run()
        for name, ok in r["checks"].items():
            if not ok:
                failures.append(f"quant_ppl:{name}")

    section("summary")
    print(f"benchmarks completed in {time.time()-t0:.0f}s; "
          f"{'ALL CHECKS PASS' if not failures else 'FAILURES: ' + str(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Tables 1/2/5/6/7 analogue: perplexity across quantization configs/methods.

Trains the shared benchmark LM on the synthetic distribution, then measures
held-out PPL for every (bits × method) cell:

  methods: fp        — no quantization (paper's FP16 row)
           rtn       — round-to-nearest, no calibration
           abq       — the paper's full pipeline (SmoothQuant-init balance
                       vectors + learnable clipping + compensation,
                       DLC + AKL block-wise calibration)
           abq-mse   — ablation: same learnables, OmniQuant-style MSE loss
  configs: W8A8, W6A6, W4A8, W4A4, W3A8, W2A8, W2*A8, W2*A16, W4A4-g64

Directional claims validated (EXPERIMENTS.md §Repro):
  (1) bit balance: ppl(W2*A8) < ppl(W2A8)      [paper Table 1/2]
  (2) calibration: ppl(abq) <= ppl(rtn) at low bits [Table 2]
  (3) monotone in W bits at fixed method       [Tables 6/7]
  (4) W8A8 ~ fp                                 [Table 7 W8A8 row]
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_bench_model
from repro.core.calibration import CalibConfig, calibrate_model, stack_qstates
from repro.data.synthetic import calibration_segments
from repro.eval.ppl import bucket_accuracy, perplexity
from repro.models.quantized import QuantizeConfig, quantize_model

CONFIGS = [
    ("W8A8", 8, 8, False, 0),
    ("W6A6", 6, 6, False, 0),
    ("W4A8", 4, 8, False, 0),
    ("W4A4", 4, 4, False, 0),
    ("W3A8", 3, 8, False, 0),
    ("W2A8", 2, 8, False, 0),
    ("W2*A8", 2, 8, True, 0),
    ("W2*A6", 2, 6, True, 0),
    ("W4A4-g64", 4, 4, False, 64),
]

# calibration is the expensive step (block-wise AdamW per config); run the
# paper's full DLC+AKL pipeline on the configs where it matters (low bits,
# the paper's W2* flagship, and the W4A4 battleground) and the MSE ablation
# once; everything else reports RTN (the paper's own tables do the same for
# high-bit rows).
_CALIBRATED = {"W4A4", "W2A8", "W2*A8", "W2*A6"}
_MSE_ABLATION = {"W2*A8"}


def run(print_fn=print) -> dict:
    params, cfg, ctx = trained_bench_model()
    results: dict[str, float] = {}
    ppl_fp = perplexity(params, cfg, ctx)
    acc_fp = bucket_accuracy(params, cfg, ctx)
    results["fp,none"] = ppl_fp
    print_fn(f"quant_ppl,fp,none,ppl={ppl_fp:.3f},bucket_acc={acc_fp:.3f}")

    import jax

    calib_tokens = jnp.asarray(calibration_segments(
        cfg.vocab_size, n_segments=2, seq_len=64, batch=2))

    # one calibration per (w,a,bb,loss) combination we report
    calib_cache: dict = {}

    def get_calib(w, a, bb, loss):
        key = (w, a, bb, loss)
        if key not in calib_cache:
            ccfg = CalibConfig(w_bits=w, a_bits=a, bit_balance=bb,
                               epochs=4, loss=loss)
            states = calibrate_model(params, calib_tokens, cfg, ccfg)
            calib_cache[key] = {"blocks": stack_qstates(states)}
        return calib_cache[key]

    for name, w, a, bb, gs in CONFIGS:
        qcfg = QuantizeConfig(w_bits=w, a_bits=a, bit_balance=bb,
                              group_size=gs)
        methods = ["rtn"]
        if name in _CALIBRATED:
            methods.append("abq")
        if name in _MSE_ABLATION:
            methods.append("abq-mse")
        for method in methods:
            if method == "rtn":
                qp = quantize_model(params, cfg, qcfg)
            else:
                loss = "dlc_akl" if method == "abq" else "mse"
                qp = quantize_model(params, cfg, qcfg,
                                    calib=get_calib(w, a, bb, loss))
            ppl = perplexity(qp, cfg, ctx)
            acc = bucket_accuracy(qp, cfg, ctx)
            results[f"{name},{method}"] = ppl
            print_fn(f"quant_ppl,{name},{method},ppl={ppl:.3f},"
                     f"bucket_acc={acc:.3f}")

    # -- directional validations (the paper's claims) --
    checks = {
        "bit_balance_helps(W2*A8<W2A8,abq)":
            results["W2*A8,abq"] < results["W2A8,abq"],
        "calibration_helps(W2*A8 abq<=rtn)":
            results["W2*A8,abq"] <= results["W2*A8,rtn"] * 1.02,
        "monotone_bits(W8A8<=W4A8<=W2A8, rtn)":
            results["W8A8,rtn"] <= results["W4A8,rtn"] * 1.02
            <= results["W2A8,rtn"] * 1.05,
        "w8a8_close_to_fp":
            results["W8A8,rtn"] < ppl_fp * 1.05,
    }
    for k, ok in checks.items():
        print_fn(f"quant_ppl_check,{k},{'PASS' if ok else 'FAIL'}")
    results["checks"] = checks
    return results


if __name__ == "__main__":
    run()

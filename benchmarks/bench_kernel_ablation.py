"""Table 4 analogue: kernel-optimization ablation on the TPU cost model.

The paper ablates {pipeline optimization, GEMV elimination, auto kernel
search} on GPU wall-clock. The TPU equivalents (DESIGN.md §2) are evaluated
on the v5e roofline cost model for the decode GEMV (1,4096)×(4096,4096),
W2A8:

  native        — no HBM/MXU overlap (bytes-time + compute-time ADD),
                  weights read as dequantized int8 (no bit-plane packing),
                  default 128³ blocking
  +pipeline     — double-buffered HBM→VMEM streams (times MAX, not ADD) —
                  Pallas provides this automatically; the ablation shows its
                  modeled contribution
  +bitplane     — packed 2-bit planes instead of int8 weights (the paper's
                  GEMV Elimination analogue: shrink the bytes the GEMV must
                  move — DESIGN.md §2)
  +auto search  — pick (BM, BN, BK) minimizing modeled time under the VMEM
                  budget (the paper's Auto Kernel Search)

Also prints the chosen block configuration per step.
"""

from __future__ import annotations

import itertools

HBM_BW = 819e9
INT8_PEAK = 394e12
VMEM_BYTES = 128 * 2**20  # v5e VMEM per core (approx)


def kernel_model(m, k, n, *, w_bits, packed, overlap, bm, bn, bk):
    """HBM traffic + MXU time for a tiled GEMM with bit-plane weights."""
    m_eff = max(m, 8)
    planes = w_bits if packed else 8  # unpacked = int8 container
    # weight tiles stream once per (M/bm) pass
    passes = max(m_eff // bm, 1)
    w_bytes = passes * (planes * k * n / 8)
    a_bytes = (n // bn) * (m_eff * k)  # act tile re-read per N block
    o_bytes = 2 * m_eff * n
    total_bytes = w_bytes + a_bytes + o_bytes
    ops = 2.0 * m_eff * k * n * planes
    t_mem = total_bytes / HBM_BW
    t_cmp = ops / INT8_PEAK
    t = max(t_mem, t_cmp) if overlap else t_mem + t_cmp
    # VMEM: x tile + unpacked w tile + acc + packed tile
    vmem = bm * bk + bk * bn + 4 * bm * bn + planes * bk * bn / 8
    return {"t_us": t * 1e6, "bytes": total_bytes, "vmem": vmem}


def auto_search(m, k, n, *, w_bits, packed, overlap):
    best = None
    for bm, bn, bk in itertools.product((8, 16, 32, 64, 128, 256),
                                        (128, 256, 512),
                                        (128, 256, 512, 1024, 2048)):
        if bk > k or bn > n:
            continue
        r = kernel_model(m, k, n, w_bits=w_bits, packed=packed,
                         overlap=overlap, bm=bm, bn=bn, bk=bk)
        if r["vmem"] > VMEM_BYTES // 4:  # double-buffering head-room
            continue
        if best is None or r["t_us"] < best[1]["t_us"]:
            best = ((bm, bn, bk), r)
    return best


def run(print_fn=print) -> dict:
    m, k, n = 1, 4096, 4096
    default_blocks = dict(bm=128, bn=128, bk=512)
    steps = []
    steps.append(("native", kernel_model(
        m, k, n, w_bits=2, packed=False, overlap=False, **default_blocks)))
    steps.append(("+pipeline", kernel_model(
        m, k, n, w_bits=2, packed=False, overlap=True, **default_blocks)))
    steps.append(("+bitplane(GEMV-elim analogue)", kernel_model(
        m, k, n, w_bits=2, packed=True, overlap=True, **default_blocks)))
    blocks, best = auto_search(m, k, n, w_bits=2, packed=True, overlap=True)
    steps.append((f"+auto_search{blocks}", best))

    results = {}
    base = steps[0][1]["t_us"]
    for name, r in steps:
        results[name] = r["t_us"]
        print_fn(f"kernel_ablation,{name},modeled_us={r['t_us']:.2f},"
                 f"speedup_vs_native={base / r['t_us']:.2f},"
                 f"bytes={r['bytes']:.3e}")
    total_speedup = base / steps[-1][1]["t_us"]
    # paper achieves 7.47x from its ablations; our byte-dominated model
    # should show a healthy multiple as well
    print_fn(f"kernel_ablation_check,total_speedup>=2,"
             f"{'PASS' if total_speedup >= 2 else 'FAIL'}"
             f" (total={total_speedup:.2f}x)")
    results["total_speedup"] = total_speedup
    return results


if __name__ == "__main__":
    run()

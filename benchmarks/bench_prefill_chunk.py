"""Chunked-prefill attention benchmark: prefix-clamped kernel vs naive.

Two measurements, written to ``BENCH_prefill_chunk.json`` so the
chunked-prefill perf trajectory is tracked PR over PR (the prefill-side
companion of `bench_decode_attn`'s decode numbers):

1. **Modeled HBM cache bytes per chunk** (v5e roofline accounting,
   `tuning.chunk_attn_cost`) at LLaMA-7B attention shapes, S = 4096,
   C = 128, swept over chunk offsets (prefix lengths start+C ∈ {C, S/8,
   S/2, S}). The naive path (the pre-kernel `attend_chunk` math, kept as
   ``REPRO_CHUNK_ATTN=naive``) dequantizes and masks the **whole max_len
   row** per chunk and round-trips the (B, C, KVH, G, S) logits/probs
   through HBM — its bytes are flat in the prefix. The Pallas kernel
   fetches ``ceil((start+C)/block_s)`` blocks only (scalar-prefetched
   clamp) and keeps the softmax state in VMEM; the XLA fallback streams
   the power-of-two prefix bucket. The gates (``run.py --check``,
   failure name ``prefill_chunk_bytes``):

   * kernel bytes **scale with the prefix length, not max_len** — strictly
     monotone in start, and the short-prefix cost is identical across
     different max_len capacities;
   * >= 4x total-traffic reduction vs naive at prefix << max_len
     (prefix = S/8), strictly fewer bytes everywhere;
   * the bucketed XLA fallback also beats naive at prefix << max_len.

2. **Smoke chunked-prefill throughput** (CPU, tiny engine): wall-clock
   tok/s of a chunked-prefill engine under ``REPRO_CHUNK_ATTN`` xla vs
   naive (on CPU the pallas mode falls back to the bucketed xla math, so
   this guards dispatch overhead + the bucketing win at small scale).
   CPU-indicative only; the modeled bytes carry the TPU claim.

Usage: PYTHONPATH=src python -m benchmarks.bench_prefill_chunk [--no-smoke]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.kernels import tuning

# LLaMA-7B attention at chunked prefill: the engine prefills ONE slot at a
# time (B=1), 32 heads (MHA), head_dim 128, max_len-sized cache rows
BATCH = 1
N_HEADS = 32
N_KV_HEADS = 32
HEAD_DIM = 128
CHUNK = 128
SEQ_LEN = 4096
ALT_SEQ_LEN = 1024  # capacity-independence probe (same prefix, smaller S)

# CPU wall-clock slack for the smoke non-regression check (containers are
# noisy; the modeled bytes are the real gate)
SMOKE_SLACK = 0.5


def naive_bytes(s: int, start: int) -> dict:
    """Modeled HBM traffic of the naive full-S path for one chunk.

    Reads the whole S-length int8 cache + scales regardless of ``start``,
    materializes the f32 dequantized k/v copies (written then read by the
    einsums) and the (B, C, KVH, G, S) f32 logits and probs (each written
    then read back) — every term is O(S), none is O(prefix).
    """
    del start  # read-then-mask: the tail is streamed anyway
    rows = BATCH * N_KV_HEADS
    pos_bytes = 2 * HEAD_DIM + 2 * 4  # int8 k+v, f32 k/v scales
    cache = rows * s * pos_bytes
    dequant = rows * s * HEAD_DIM * 4 * 2 * 2  # f32 k,v copies: write + read
    inter = BATCH * N_HEADS * CHUNK * s * (4 + 4) * 2  # logits, probs r/w
    qo = BATCH * CHUNK * N_HEADS * HEAD_DIM * (4 + 4)
    return {"cache": float(cache),
            "total": float(cache + dequant + inter + qo)}


def pallas_bytes(s: int, start: int) -> dict:
    """Modeled HBM traffic of the prefix-clamped kernel for one chunk:
    one pass over the blocks covering start+C, nothing S-sized written."""
    group = N_HEADS // N_KV_HEADS
    cand = tuning.best_chunk_attn_block(BATCH, N_KV_HEADS, group, CHUNK, s,
                                        HEAD_DIM)
    r = tuning.chunk_attn_cost(BATCH, N_KV_HEADS, group, CHUNK, s, HEAD_DIM,
                               block_s=cand.block_s, start=start)
    qo = BATCH * CHUNK * N_HEADS * HEAD_DIM * (4 + 4)
    return {"cache": float(r["cache_bytes"]),
            "total": float(r["cache_bytes"] + qo),
            "block_s": cand.block_s}


def xla_bucket_bytes(s: int, start: int) -> dict:
    """Modeled HBM traffic of the prefix-bucketed XLA fallback: the cache
    slice streamed is the power-of-two bucket over start+C (the engine's
    `_prefix_bucket` rounding), not max_len."""
    end = start + CHUNK
    bucket = 1
    while bucket < end:
        bucket <<= 1
    bucket = min(bucket, s)
    rows = BATCH * N_KV_HEADS
    pos_bytes = 2 * HEAD_DIM + 2 * 4
    cache = rows * bucket * pos_bytes
    qo = BATCH * CHUNK * N_HEADS * HEAD_DIM * (4 + 4)
    return {"cache": float(cache), "total": float(cache + qo),
            "bucket": bucket}


def smoke_chunk_tok_s(mode: str, gen: int = 4) -> float:
    """Tiny chunked-prefill engine wall-clock tok/s under one
    REPRO_CHUNK_ATTN mode (CPU: pallas falls back to the bucketed xla)."""
    from repro.launch.serve import Server

    prev = os.environ.get("REPRO_CHUNK_ATTN")
    os.environ["REPRO_CHUNK_ATTN"] = mode
    try:
        server = Server(arch="qwen3-4b", smoke=True, w_bits=4, max_len=128)
        engine = server.engine(n_slots=2, fresh=True, prefill_bucket=8,
                               prefill_chunk=16)
        prompts = [list(range(1, 49)), list(range(3, 35))]
        _, stats = engine.generate(prompts, max_new_tokens=gen)  # warmup
        _, stats = engine.generate(prompts, max_new_tokens=gen)
        return stats["decode_tok_s"]
    finally:
        if prev is None:
            os.environ.pop("REPRO_CHUNK_ATTN", None)
        else:
            os.environ["REPRO_CHUNK_ATTN"] = prev


def run(print_fn=print, smoke: bool = True,
        out_path: str = "BENCH_prefill_chunk.json") -> dict:
    results: dict = {"shapes": {"batch": BATCH, "n_heads": N_HEADS,
                                "n_kv_heads": N_KV_HEADS,
                                "head_dim": HEAD_DIM, "chunk": CHUNK,
                                "seq_len": SEQ_LEN},
                     "prefixes": {}}
    s = SEQ_LEN
    prefixes = [CHUNK, s // 8, s // 2, s]  # start + CHUNK
    ok = True
    prev_cache = None
    for prefix in prefixes:
        start = prefix - CHUNK
        nv = naive_bytes(s, start)
        pb = pallas_bytes(s, start)
        xb = xla_bucket_bytes(s, start)
        ratio = nv["total"] / pb["total"]
        ratio_xla = nv["total"] / xb["total"]
        fewer = pb["total"] < nv["total"]
        # block granularity: bytes are non-decreasing step-wise in the
        # prefix (strict growth is gated smallest-vs-largest below)
        monotone = prev_cache is None or pb["cache"] >= prev_cache
        prev_cache = pb["cache"]
        ok = ok and fewer and monotone
        results["prefixes"][str(prefix)] = {
            "start": start,
            "block_s": pb["block_s"],
            "bucket": xb["bucket"],
            "bytes_naive": nv["total"],
            "bytes_pallas": pb["total"],
            "bytes_xla_bucketed": xb["total"],
            "cache_bytes_naive": nv["cache"],
            "cache_bytes_pallas": pb["cache"],
            "reduction_vs_naive": ratio,
            "reduction_xla_vs_naive": ratio_xla,
        }
        print_fn(
            f"prefill_chunk_bytes,S={s},prefix={prefix},bs={pb['block_s']},"
            f"naive={nv['total']:.3e},pallas={pb['total']:.3e},"
            f"xla_bucket={xb['total']:.3e},reduction={ratio:.1f}x,"
            f"{'PASS' if fewer and monotone else 'FAIL'}")

    # >= 4x traffic reduction at prefix << max_len (the acceptance gate),
    # for the kernel AND the bucketed XLA fallback
    small = results["prefixes"][str(s // 8)]
    reduction_ok = (small["reduction_vs_naive"] >= 4.0
                    and small["reduction_xla_vs_naive"] >= 4.0)
    # prefix scaling, not capacity scaling: the same short prefix costs the
    # same kernel bytes in a 4x smaller cache (naive scales with capacity)
    alt = tuning.chunk_attn_cost(
        BATCH, N_KV_HEADS, 1, CHUNK, ALT_SEQ_LEN, HEAD_DIM,
        block_s=results["prefixes"][str(CHUNK)]["block_s"], start=0)
    base = tuning.chunk_attn_cost(
        BATCH, N_KV_HEADS, 1, CHUNK, SEQ_LEN, HEAD_DIM,
        block_s=results["prefixes"][str(CHUNK)]["block_s"], start=0)
    capacity_independent = alt["cache_bytes"] == base["cache_bytes"]
    strict_growth = (results["prefixes"][str(s)]["cache_bytes_pallas"]
                     > results["prefixes"][str(CHUNK)]["cache_bytes_pallas"])
    ok = ok and reduction_ok and capacity_independent and strict_growth
    results["strict_growth"] = strict_growth
    results["reduction_at_small_prefix_ok"] = reduction_ok
    results["capacity_independent"] = capacity_independent
    results["prefix_scaling_ok"] = ok
    print_fn(f"prefill_chunk_check,bytes_scale_with_prefix,"
             f"{'PASS' if ok else 'FAIL'}")

    if smoke:
        tx = smoke_chunk_tok_s("xla")
        tn = smoke_chunk_tok_s("naive")
        results["smoke_tok_s_xla"] = tx
        results["smoke_tok_s_naive"] = tn
        not_regressed = tx >= SMOKE_SLACK * tn
        results["smoke_not_regressed"] = not_regressed
        print_fn(f"prefill_chunk_smoke,xla_tok_s={tx:.1f},"
                 f"naive_tok_s={tn:.1f},"
                 f"{'PASS' if not_regressed else 'FAIL'}  (CPU-indicative)")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print_fn(f"prefill_chunk_bench,wrote={out_path}")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--no-smoke", action="store_true",
                   help="skip the tiny-engine wall-clock section")
    p.add_argument("--out", default="BENCH_prefill_chunk.json")
    args = p.parse_args(argv)
    r = run(smoke=not args.no_smoke, out_path=args.out)
    return 0 if r["prefix_scaling_ok"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())

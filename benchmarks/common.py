"""Shared benchmark utilities: the trained tiny LM every accuracy benchmark
quantizes, plus timing helpers."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.ckpt import checkpoint as ckpt
from repro.configs import ArchConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.blocks import ModelContext

# The benchmark model: llama-family (the paper's eval family), sized so CPU
# training reaches a clearly-learned state in ~2 minutes.
BENCH_CFG = ArchConfig(
    name="bench-llama", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=8, d_ff=384, vocab_size=512,
)

_CKPT_DIR = os.environ.get("REPRO_BENCH_CKPT", "/tmp/repro_bench_model")
_TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "400"))


def trained_bench_model(steps: int = _TRAIN_STEPS, seed: int = 0):
    """Train (or load the cached) benchmark LM. Returns (params, cfg, ctx)."""
    cfg = BENCH_CFG
    ctx = ModelContext(cfg=cfg, remat=False)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg, dtype=jnp.float32)

    last = ckpt.latest_step(_CKPT_DIR)
    if last == steps:
        params = ckpt.restore_like(_CKPT_DIR, steps, params)
        return params, cfg, ctx

    opt_cfg = optim.AdamWConfig(lr=2e-3, grad_clip_norm=1.0)
    opt_state = optim.init(params, opt_cfg)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128))

    @jax.jit
    def step(p, o, batch, i):
        def loss_of(pp):
            return lm.loss_fn(pp, batch, cfg, ctx, n_loss_chunks=4)[0]

        loss, grads = jax.value_and_grad(loss_of)(p)
        lr = optim.cosine_with_warmup(i, base_lr=opt_cfg.lr, warmup=40,
                                      total=steps)
        p, o = optim.update(grads, o, p, opt_cfg, lr_scale=lr / opt_cfg.lr)
        return p, o, loss

    t0 = time.time()
    for i in range(steps):
        b = ds.batch(i, 8)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step(params, opt_state, b, jnp.asarray(i))
        if i % 100 == 0:
            print(f"  [bench-train] step={i} loss={float(loss):.3f}", flush=True)
    print(f"  [bench-train] done in {time.time()-t0:.0f}s "
          f"final loss={float(loss):.3f}", flush=True)
    ckpt.save(_CKPT_DIR, steps, params)
    return params, cfg, ctx


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds per call (CPU; indicative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)

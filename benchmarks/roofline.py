"""Roofline derivation from the dry-run's compiled artifacts (§Roofline).

Per (arch × shape × mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw
(cost_analysis is post-SPMD per-device — verified in tests — so no /chips.)

Plus the "useful work" anchors:
  MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve), N_active excludes
  non-routed experts; ratio MODEL_FLOPS/(HLO_FLOPs·chips) exposes remat and
  padding waste.
  For decode (memory-bound by construction) the roofline fraction is
  ideal_bytes / HLO_bytes: ideal = packed weights + KV/state cache, the bytes
  one step MUST move.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dryrun benchmarks/results/dryrun.json]
      [--mesh 256] [--format md|json]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Optional

# v5e target (DESIGN.md §9)
PEAK_BF16 = 197e12  # FLOP/s per chip
PEAK_INT8 = 394e12
HBM_BW = 819e9  # B/s per chip
ICI_LINK = 50e9  # B/s per link

_ARCH_CACHE: dict = {}


def _arch_stats(arch: str) -> dict:
    """Param counts (total / active) + serve-path byte footprints."""
    if arch in _ARCH_CACHE:
        return _ARCH_CACHE[arch]
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.quantized import QuantizeConfig, quantize_model

    cfg = get_config(arch).with_kv_replication(16)
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)

    def count(tree):
        return sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(tree))

    n_total = count(shapes)
    n_expert = 0
    if cfg.family == "moe":
        moe = shapes["blocks"]["moe"]
        n_expert = sum(count(moe[k]) for k in ("w_gate", "w_up", "w_down"))
    n_active = n_total - n_expert * (1 - cfg.top_k / max(cfg.n_experts, 1))

    qcfg = QuantizeConfig(w_bits=2, a_bits=8, bit_balance=True, tensor_par=16)
    q_shapes = jax.eval_shape(lambda p: quantize_model(p, cfg, qcfg), shapes)
    q_bytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                  for s in jax.tree_util.tree_leaves(q_shapes))

    _ARCH_CACHE[arch] = {
        "cfg": cfg, "n_total": n_total, "n_active": n_active,
        "serve_weight_bytes": q_bytes,
    }
    return _ARCH_CACHE[arch]


def _cache_bytes(arch: str, batch: int, seq_len: int) -> int:
    import jax
    import numpy as np

    from repro.models import lm

    cfg = _arch_stats(arch)["cfg"]
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq_len))
    return sum(int(np.prod(s.shape)) * s.dtype.itemsize
               for s in jax.tree_util.tree_leaves(cache))


def _probe_total(pr: dict, vals: list) -> float:
    """Exact depth/batch extrapolation of an unrolled probe pair."""
    g1, g2 = pr["gs"]
    v1, v2 = vals
    slope = (v2 - v1) / (g2 - g1)
    scale_b = pr["batch_real"] / pr["batch_probe"]
    return (v1 + slope * (pr["g_real"] - g1)) * scale_b


def analyze_cell(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    from repro.configs import SHAPES

    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    pr = rec.get("probe")
    if pr:
        # probe-corrected per-device totals (scan bodies fully counted);
        # bytes are TPU-adjusted: minus XLA:CPU int8-dot materialization and
        # donation-elided cache-threading copies (dryrun.tpu_artifact_bytes)
        flops_dev = _probe_total(pr, pr["flops"])
        raw_bytes = _probe_total(pr, pr["bytes"])
        art = _probe_total(pr, pr.get("artifact_bytes", [0, 0]))
        bytes_dev = max(raw_bytes - art, raw_bytes * 0.1)
        coll_bytes = _probe_total(pr, pr["coll"])
        coll = rec.get("collective_bytes_per_device", {})
    else:
        # full-module numbers: while-loop bodies counted ONCE (lower bound)
        flops_dev = rec["flops_per_device"]
        bytes_dev = rec["bytes_per_device"]
        coll = rec.get("collective_bytes_per_device", {})
        coll_bytes = sum(coll.values())

    t_compute = flops_dev / PEAK_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes / ICI_LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    stats = _arch_stats(rec["arch"])
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        model_flops = 6 * stats["n_active"] * tokens
    else:
        model_flops = 2 * stats["n_active"] * tokens
    flops_ratio = model_flops / max(flops_dev * chips, 1.0)

    out = {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_global": flops_dev * chips,
        "flops_ratio": flops_ratio,
        "probe_corrected": bool(pr),
        "coll_breakdown": {k: v for k, v in coll.items() if v},
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }

    if shape.kind == "decode":
        ideal = (stats["serve_weight_bytes"]
                 + _cache_bytes(rec["arch"], shape.global_batch,
                                shape.seq_len)) / chips
        out["ideal_bytes_per_dev"] = ideal
        out["roofline_fraction"] = min(ideal / max(bytes_dev, 1.0), 1.0)
        out["fraction_kind"] = "bytes(ideal/HLO)"
    else:
        # MFU-style: useful-compute time over the binding term
        t_useful = model_flops / chips / PEAK_BF16
        out["roofline_fraction"] = t_useful / max(max(terms.values()), 1e-12)
        out["fraction_kind"] = "MFU-proxy"
    out["suggestion"] = _suggest(out, shape)
    return out


def _suggest(out: dict, shape) -> str:
    d = out["dominant"]
    if d == "collective":
        return ("collective-bound: overlap/reschedule the all-gathers "
                "(fsdp prefetch) or widen per-chip shards")
    if d == "memory":
        if shape.kind == "decode":
            return ("memory-bound (the ABQ regime): cut remaining HLO bytes "
                    "— fuse dequant epilogues, drop fp32 scale reads, "
                    "shrink KV scales")
        return ("memory-bound: increase arithmetic intensity (fuse "
                "elementwise chains, larger microbatch per chip, bf16 "
                "intermediates)")
    if out["flops_ratio"] < 0.5:
        return ("compute-bound with low useful-FLOP ratio: reduce remat "
                "recompute or padding FLOPs")
    return "compute-bound near peak: tune matmul tiling / layouts"


def load(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def render_md(rows: list[dict]) -> str:
    hdr = ("| arch | shape | chips | compute s | memory s | collective s | "
           "dominant | roofline frac | MODEL/HLO flops |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} ({r['fraction_kind']}"
            f"{'' if r['probe_corrected'] else '; body-once LB'}) "
            f"| {r['flops_ratio']:.2f} |")
    return "\n".join(lines)


def merge_probes(records: list[dict], probes_dir: Optional[str]) -> None:
    """Attach probe measurements (separate --probes-only runs) by cell key."""
    if not probes_dir or not os.path.isdir(probes_dir):
        return
    by_key = {}
    for fname in os.listdir(probes_dir):
        if not fname.endswith(".json"):
            continue
        try:
            for rec in load(os.path.join(probes_dir, fname)):
                if rec.get("probe"):
                    by_key[(rec["arch"], rec["shape"],
                            rec["n_devices"])] = rec["probe"]
        except Exception:
            continue
    for rec in records:
        key = (rec.get("arch"), rec.get("shape"), rec.get("n_devices"))
        if key in by_key and "probe" not in rec:
            rec["probe"] = by_key[key]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dryrun", default="benchmarks/results/dryrun.json")
    p.add_argument("--probes-dir", default="benchmarks/results/probes")
    p.add_argument("--mesh", type=int, default=256,
                   help="report cells for this device count (256|512|0=all)")
    p.add_argument("--format", default="md", choices=["md", "json"])
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)

    records = load(args.dryrun)
    merge_probes(records, args.probes_dir)
    rows = []
    for rec in records:
        if args.mesh and rec.get("n_devices") != args.mesh:
            continue
        r = analyze_cell(rec)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["shape"], r["arch"]))
    if args.format == "md":
        text = render_md(rows)
    else:
        text = json.dumps(rows, indent=1)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())

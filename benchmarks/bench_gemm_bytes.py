"""Fig. 5 / Tables 13–14 analogue: GEMV/GEMM throughput model per bit combo.

The paper measures TOPS of ABQKernel vs cuBLAS/CUTLASS W8A8/W4A4 on RTX
GPUs. On TPU the dry-run container cannot measure wall-clock, so this
benchmark reports the v5e roofline-model throughput for the same LLaMA-7B
matrix shapes: time = max(bytes/HBM_bw, ops/int8_peak); TOPS = 2MNK/time.

Weight bytes are the *packed* footprints our engine actually reads
(bit-planes + scales), activations int8 + f32 scales, outputs bf16 —
mirroring the Pallas kernel's data movement. The W8A8 row doubles as the
SmoothQuant/cuBLAS baseline, so `speedup_vs_w8a8` is the analogue of the
paper's 7.47× GEMV win (theirs: BTC vs INT8 TensorCore; ours: HBM bytes).

It also validates the kernel numerics once per shape against the ref oracle
(interpret mode) and reports the measured CPU-interpret microseconds as
`us_per_call` (indicative only — NOT the modeled TPU time).
"""

from __future__ import annotations

import numpy as np

HBM_BW = 819e9
INT8_PEAK = 394e12

# the paper's LLaMA-7B GEMV/GEMM shapes (Fig. 5, Tables 13-14)
SHAPES = [
    (1, 4096, 4096),
    (1, 11008, 4096),
    (1, 4096, 11008),
    (8, 4096, 4096),
    (8, 11008, 4096),
]

BITS = [(2, 8), (2, 4), (3, 8), (4, 8), (4, 4), (6, 6), (8, 8)]


def modeled_time(m: int, k: int, n: int, w_bits: int, a_bits: int,
                 bit_balance: bool = False) -> dict:
    planes = w_bits if not bit_balance else w_bits + 1
    w_bytes = planes * k * n / 8 + 2 * 4 * n  # packed planes + scale/zp
    a_bytes = m * k + 4 * m  # int8 acts + f32 scales
    o_bytes = 2 * m * n
    total_bytes = w_bytes + a_bytes + o_bytes
    # ops: one int8 MXU matmul per plane (weight-side decomposition)
    ops = 2.0 * m * k * n * planes
    t = max(total_bytes / HBM_BW, ops / INT8_PEAK)
    return {"t": t, "bytes": total_bytes, "ops": ops,
            "tops": 2.0 * m * k * n / t / 1e12}


def run(print_fn=print) -> dict:
    results = {}
    for (m, k, n) in SHAPES:
        base = modeled_time(m, k, n, 8, 8)
        for (w, a) in BITS:
            r = modeled_time(m, k, n, w, a)
            key = f"({m},{k})x({k},{n}),w{w}a{a}"
            speedup = base["t"] / r["t"]
            results[key] = {"tops": r["tops"], "speedup_vs_w8a8": speedup}
            print_fn(f"gemm_model,{key},tops={r['tops']:.2f},"
                     f"speedup_vs_w8a8={speedup:.2f}")

    # numerics spot-check: pallas-interpret vs oracle on a reduced shape
    import jax.numpy as jnp

    from benchmarks.common import time_call
    from repro.core import QuantSpec, act_scales, pack_weight, quantize_act
    from repro.kernels import ref as R
    from repro.kernels.abq_matmul import abq_matmul_pallas

    rng = np.random.default_rng(0)
    m, k, n = 8, 512, 256
    wmat = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    pw = pack_weight(wmat, QuantSpec(bits=2, bit_balance=True))
    aspec = QuantSpec(bits=8, symmetric=True, granularity="per_token")
    xs = act_scales(x, aspec)
    xq = quantize_act(x, xs, aspec)
    y_ref = R.abq_matmul_ref(xq, xs, pw.planes, pw.scale, pw.zero_point, k,
                             out_dtype=jnp.float32)
    us = time_call(
        lambda: abq_matmul_pallas(xq, xs, pw.planes, pw.scale, pw.zero_point,
                                  block_m=8, block_n=128, block_k=256,
                                  out_dtype=jnp.float32, interpret=True))
    y_pal = abq_matmul_pallas(xq, xs, pw.planes, pw.scale, pw.zero_point,
                              block_m=8, block_n=128, block_k=256,
                              out_dtype=jnp.float32, interpret=True)
    err = float(jnp.max(jnp.abs(y_pal - y_ref)))
    print_fn(f"gemm_kernel_check,w2*a8_{m}x{k}x{n},us_per_call={us:.0f},"
             f"max_err_vs_ref={err:.2e}")
    results["kernel_check_err"] = err

    # paper-alignment: decode GEMV W2A8 speedup vs W8A8 should exceed ~3x
    # (bytes ratio ~10/8... packed 2 planes vs 8 -> ~3.5-4x at these shapes)
    key = "(1,4096)x(4096,4096),w2a8"
    results["gemv_w2a8_speedup"] = results[key]["speedup_vs_w8a8"]
    print_fn(f"gemm_check,gemv_w2a8_speedup>=3,"
             f"{'PASS' if results[key]['speedup_vs_w8a8'] >= 3 else 'FAIL'}")
    return results


if __name__ == "__main__":
    run()

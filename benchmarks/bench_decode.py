"""Decode fast-path benchmark: fused ReQuant+GEMM vs the unfused baseline.

Two measurements per (W, A) config, written to ``BENCH_decode.json`` so the
decode perf trajectory is tracked PR over PR:

1. **Modeled HBM bytes per decoded token** (v5e roofline accounting, the
   same machinery as `bench_gemm_bytes` / `tuning.model_cost`) for one
   transformer block's worth of quantized linears at LLaMA-7B shapes.
   The unfused path charges the act_quant round-trip (bf16 read + int8/scale
   write, then int8/scale read by the GEMM); the fused path reads the bf16
   activation once inside the GEMM kernel. The fused total must be
   **strictly lower** — that is the acceptance gate.

2. **Smoke decode throughput** (CPU, XLA path, tiny model): wall-clock
   tok/s of `Server.generate`'s scan decode with the fusion on vs off
   (``REPRO_ABQ_FUSED``). Indicative only on CPU; the modeled bytes carry
   the TPU claim.

Usage: PYTHONPATH=src python -m benchmarks.bench_decode [--no-smoke]
"""

from __future__ import annotations

import argparse
import json
import os

# LLaMA-7B decode-step linears (per block): qkv/o + gate/up/down
DECODE_LINEARS = [
    ("wq", 4096, 4096),
    ("wk", 4096, 4096),
    ("wv", 4096, 4096),
    ("wo", 4096, 4096),
    ("w_gate", 4096, 11008),
    ("w_up", 4096, 11008),
    ("w_down", 11008, 4096),
]

CONFIGS = [("W2A8", 2, 8), ("W4A8", 4, 8)]


def linear_bytes(m: int, k: int, n: int, w_bits: int, *, fused: bool) -> dict:
    """Modeled HBM traffic for one quantized linear at decode (batch=m).

    Shared terms: packed weight planes + scale/zp stream once (decode's
    single M pass), output written bf16.
    Unfused adds the ReQuant round-trip: bf16 act read by act_quant, int8
    act + f32 scale written to HBM, then read back by the GEMM kernel.
    Fused reads the bf16 activation once, in the GEMM prologue.
    """
    w_bytes = w_bits * k * n / 8 + 2 * 4 * n  # planes + f32 scale/zp
    out_bytes = 2 * m * n
    act_bf16 = 2 * m * k
    act_int8 = m * k + 4 * m  # container + per-token scale
    if fused:
        act_bytes = act_bf16
    else:
        act_bytes = act_bf16 + 2 * act_int8  # write then read back
    return {"total": w_bytes + act_bytes + out_bytes,
            "weights": w_bytes, "acts": act_bytes, "out": out_bytes}


def modeled_bytes_per_token(batch: int, w_bits: int, *,
                            fused: bool) -> tuple[float, float]:
    """(total, activation-stream) bytes over one block's linears, per
    decoded token. Decode is weight-bound, so the total moves by fractions
    of a percent while the activation stream — the thing the fusion
    deletes — drops by 50% (bf16 read vs bf16 read + int8 write + int8
    read); both are tracked."""
    total = act = 0.0
    for _, k, n in DECODE_LINEARS:
        r = linear_bytes(batch, k, n, w_bits, fused=fused)
        total += r["total"]
        act += r["acts"]
    return total / batch, act / batch


def smoke_decode_tok_s(w_bits: int, *, fused: bool, gen: int = 8,
                       batch: int = 2) -> float:
    """Tiny-model wall-clock decode tok/s with the fusion toggled."""
    from repro.launch.serve import Server

    prev = os.environ.get("REPRO_ABQ_FUSED")
    os.environ["REPRO_ABQ_FUSED"] = "1" if fused else "0"
    try:
        server = Server(arch="qwen3-4b", smoke=True, w_bits=w_bits,
                        max_len=64)
        prompts = [[1, 2, 3, 4]] * batch
        # warmup at the SAME gen length: n_steps is a static jit arg, so a
        # different length would leave compilation inside the timed call
        server.generate(prompts, max_new_tokens=gen)
        _, stats = server.generate(prompts, max_new_tokens=gen)
        return stats["decode_tok_s"]
    finally:
        if prev is None:
            os.environ.pop("REPRO_ABQ_FUSED", None)
        else:
            os.environ["REPRO_ABQ_FUSED"] = prev


def run(print_fn=print, smoke: bool = True, out_path: str = "BENCH_decode.json") -> dict:
    results: dict = {"configs": {}}
    batch = 4
    ok = True
    for tag, wb, _ab in CONFIGS:
        unfused, act_u = modeled_bytes_per_token(batch, wb, fused=False)
        fused, act_f = modeled_bytes_per_token(batch, wb, fused=True)
        saved = 1.0 - fused / unfused
        act_saved = 1.0 - act_f / act_u
        strictly_less = fused < unfused
        ok = ok and strictly_less
        results["configs"][tag] = {
            "batch": batch,
            "bytes_per_token_unfused": unfused,
            "bytes_per_token_fused": fused,
            "bytes_saved_frac": saved,
            "act_stream_saved_frac": act_saved,
        }
        print_fn(f"decode_bytes,{tag},B={batch},"
                 f"unfused={unfused:.3e},fused={fused:.3e},"
                 f"saved={saved*100:.2f}%,act_stream_saved={act_saved*100:.0f}%,"
                 f"{'PASS' if strictly_less else 'FAIL'}")

    if smoke:
        for tag, wb, _ab in CONFIGS:
            tf = smoke_decode_tok_s(wb, fused=True)
            tu = smoke_decode_tok_s(wb, fused=False)
            results["configs"][tag]["smoke_tok_s_fused"] = tf
            results["configs"][tag]["smoke_tok_s_unfused"] = tu
            print_fn(f"decode_smoke,{tag},fused_tok_s={tf:.1f},"
                     f"unfused_tok_s={tu:.1f}  (CPU-indicative)")

    results["fused_strictly_fewer_bytes"] = ok
    print_fn(f"decode_check,fused_bytes_strictly_lower,"
             f"{'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print_fn(f"decode_bench,wrote={out_path}")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--no-smoke", action="store_true",
                   help="skip the tiny-model wall-clock section")
    p.add_argument("--out", default="BENCH_decode.json")
    args = p.parse_args(argv)
    r = run(smoke=not args.no_smoke, out_path=args.out)
    return 0 if r["fused_strictly_fewer_bytes"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())

"""Fig. 6 / Table 12 analogue: end-to-end weight+KV memory & latency model.

The paper reports FastTransformer inference latency/memory for FP16, W8A8
(SmoothQuant), W4A16 and W2A8 (ABQ) on LLaMA-7B/13B/30B. Here: exact byte
footprints from the real (eval_shape'd) param/cache trees of our configs,
plus the v5e decode-latency roofline model (bytes/HBM_bw per token), for
llama-7b and every assigned arch.

Validated ratios (paper §4.4): W2A8 memory ≈ FP16/4.8 and ≈ W8A8/2.7 on
LLaMA-7B (weights+cache at their serving shape).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm
from repro.models.quantized import QuantizeConfig, quantize_model

HBM_BW = 819e9


def _bytes(tree) -> int:
    return sum(int(np.prod(s.shape)) * s.dtype.itemsize
               for s in jax.tree_util.tree_leaves(tree))


def footprint(arch: str, w_bits, a_bits, bb, *, batch=8, seq=512,
              fp16=False) -> dict:
    cfg = get_config(arch)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
    if fp16:
        w_bytes = _bytes(params)
        # fp16 KV cache: same shapes as the int8 cache but 2-byte values,
        # no scales
        cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq))
        kv = sum(
            int(np.prod(s.shape)) * 2
            for path, s in jax.tree_util.tree_flatten_with_path(cache)[0]
            if not str(path).endswith("scale']")
        )
    else:
        qcfg = QuantizeConfig(w_bits=w_bits, a_bits=a_bits, bit_balance=bb,
                              tensor_par=1)
        qp = jax.eval_shape(lambda p: quantize_model(p, cfg, qcfg), params)
        w_bytes = _bytes(qp)
        kv = _bytes(jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq)))
    total = w_bytes + kv
    return {"weights_gb": w_bytes / 1e9, "kv_gb": kv / 1e9,
            "total_gb": total / 1e9,
            "decode_ms_per_tok": total / HBM_BW * 1e3}


def run(print_fn=print) -> dict:
    results = {}
    rows = [("fp16", None, None, False, True),
            ("W8A8", 8, 8, False, False),
            ("W4A8", 4, 8, False, False),
            ("W2A8", 2, 8, False, False),
            ("W2*A8", 2, 8, True, False)]
    for arch in ("llama-7b",) + tuple(a for a in ARCH_NAMES if a != "llama-7b"):
        for name, w, a, bb, fp in rows:
            f = footprint(arch, w, a, bb, fp16=fp)
            results[f"{arch},{name}"] = f
            print_fn(f"e2e_memory,{arch},{name},weights_gb={f['weights_gb']:.2f},"
                     f"kv_gb={f['kv_gb']:.2f},total_gb={f['total_gb']:.2f},"
                     f"decode_ms_per_tok={f['decode_ms_per_tok']:.2f}")

    l7 = {n: results[f"llama-7b,{n}"]["total_gb"]
          for n, *_ in rows}
    r_fp = l7["fp16"] / l7["W2A8"]
    r_w8 = l7["W8A8"] / l7["W2A8"]
    print_fn(f"e2e_check,llama7b W2A8 vs fp16 ratio={r_fp:.2f} "
             f"(paper 4.8x incl. runtime buffers), vs W8A8 ratio={r_w8:.2f} "
             f"(paper 2.7x)")
    print_fn(f"e2e_check,compression_ratios,"
             f"{'PASS' if r_fp > 3.0 and r_w8 > 1.8 else 'FAIL'}")
    results["ratio_fp16"] = r_fp
    results["ratio_w8a8"] = r_w8
    return results


if __name__ == "__main__":
    run()

"""Serving benchmark: continuous-batching engine vs the static batcher.

The engine's claim is system-level: the same kernels, the same per-step
cost, but no idle-slot work — a retired row's slot is reused immediately
instead of burning lockstep steps until the longest batchmate finishes.
Two measurements, written to ``BENCH_serving.json`` so the serving
trajectory is tracked PR over PR:

1. **Modeled slot-step account** (deterministic, the CI gate): a
   step-granular simulation of the same Poisson-arrival workload under
   both policies. The static batcher decodes batches of ``SLOTS`` requests
   in arrival order, every batch running to its longest member's budget
   (idle-slot steps are the waste); the engine admits arrivals into free
   slots between steps and retires rows at their own budgets. Per-step
   device cost is identical (same batch width, same compiled step), so the
   throughput ratio is the step-count ratio. Gate: **>= 1.5x**. Arrivals
   are charged to the engine (it waits for them) and granted to the static
   batcher for free — the model is conservative.

2. **Smoke wall-clock** (CPU, tiny model): the same workload driven
   through `Server.generate` (static) and `repro.serving.Engine`
   (continuous), reporting throughput tok/s, p50/p99 per-token latency,
   and mean slot occupancy. The engine pays a real host sync per step
   (the static scan pays one per call) and still must clear >= 1.5x.

Usage: PYTHONPATH=src python -m benchmarks.bench_serving [--no-smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

SLOTS = 8
N_REQ = 32
SEED = 3
MAX_LEN = 128
HORIZON = 8  # engine multi-step horizon (tokens per jitted step)
ARRIVAL_SCALE = 1.0  # mean inter-arrival, in decode steps (Poisson process)
# CPU wall-clock slack for the smoke gate in run.py (containers are noisy;
# the modeled slot-step account is the deterministic gate — same convention
# as bench_decode_attn's SMOKE_SLACK)
SMOKE_SLACK = 0.6


def make_workload(seed: int = SEED, n: int = N_REQ):
    """(arrival_step, prompt_len, gen_len) per request. Prompt lengths are
    bucket-aligned (8/16/24; the engine's default prefill bucket); the
    generation budgets are heavy-tailed — mostly short (2..12), a quarter
    long (60..90), the realistic serving mix. Raggedness is what the
    static batcher pays for (every batch runs to its longest member) and
    the engine does not."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(scale=ARRIVAL_SCALE, size=n)
    arrival = np.floor(np.cumsum(inter) - inter[0]).astype(int)
    long_mask = rng.random(n) < 0.25
    gens = np.where(long_mask, rng.integers(60, 91, size=n),
                    rng.integers(2, 13, size=n)).astype(int)
    plens = (rng.integers(1, 4, size=n) * 8).astype(int)
    return arrival, plens, gens


# ---------------------------------------------------------------------------
# 1) modeled slot-step account (the deterministic gate)
# ---------------------------------------------------------------------------


def modeled_slot_steps(arrival, gens, slots: int = SLOTS,
                       horizon: int = HORIZON) -> dict:
    """Device token-steps under both policies (per-step device cost is
    identical — same batch width, same compiled step — so the throughput
    ratio is the token-step ratio). The engine admits/retires at
    ``horizon``-block granularity: a row finishing mid-block wastes the
    tail of that block, which is charged to the engine."""
    gens = list(map(int, gens))
    static_steps = sum(max(gens[i:i + slots])
                       for i in range(0, len(gens), slots))
    useful = sum(gens)

    queue: list[int] = []
    active: list[int] = []
    t = inner_steps = calls = 0
    occ_sum = 0.0
    i, done = 0, 0
    n = len(gens)
    while done < n:
        while i < n and arrival[i] <= t:
            queue.append(gens[i])
            i += 1
        while queue and len(active) < slots:
            active.append(queue.pop(0))
        if active:
            inner_steps += horizon
            calls += 1
            occ_sum += len(active) / slots
            active = [g - horizon for g in active]
            done += sum(1 for g in active if g <= 0)
            active = [g for g in active if g > 0]
            t += horizon
        else:
            t += 1  # idle: waiting on the arrival process

    static_occ = useful / (static_steps * slots)
    return {
        "useful_tokens": useful,
        "static_steps": static_steps,
        "engine_steps": inner_steps,  # device token-steps (incl. block tails)
        "engine_calls": calls,
        "speedup": static_steps / max(inner_steps, 1),
        "engine_occupancy": occ_sum / max(calls, 1),
        "static_occupancy": static_occ,
    }


# ---------------------------------------------------------------------------
# 2) smoke wall-clock (tiny model, CPU-indicative)
# ---------------------------------------------------------------------------


def _pcts(lat: list) -> dict:
    if not lat:
        return {"p50_ms": 0.0, "p99_ms": 0.0}
    a = np.asarray(lat) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99))}


def _run_static(server, prompts, gens):
    """Batches of SLOTS in arrival order, lockstep to the batch max; a
    token's latency is the whole batch wall (the scan only surfaces tokens
    at the end). Useful tokens exclude the lockstep overrun rows."""
    t0 = time.time()
    lat: list[float] = []
    toks = 0
    for s in range(0, len(prompts), SLOTS):
        bp, bg = prompts[s:s + SLOTS], gens[s:s + SLOTS]
        tb = time.time()
        server.generate(bp, max_new_tokens=int(max(bg)))
        dt = time.time() - tb
        for g in bg:
            toks += int(g)
            lat += [dt] * int(g)
    return toks / max(time.time() - t0, 1e-9), lat


def _run_engine(engine, prompts, gens, arrival):
    """Poisson arrivals on the token-step clock (a horizon block advances
    it by H, an idle poll by 1 — the same clock the static batcher's steps
    tick on); per-token latency is first token from submit, then
    inter-token gaps (tokens stream per block)."""
    from repro.serving import Request

    occ0 = engine.stats["occupancy_sum"]
    dev0 = engine.stats["device_steps"]
    base_steps = engine.stats["steps"]
    t0 = time.time()
    states, i = [], 0
    while i < len(prompts) or engine.has_work():
        idle = (engine.stats["steps"] - base_steps) \
            - (engine.stats["device_steps"] - dev0)
        clock = (engine.stats["device_steps"] - dev0) * engine.step_horizon \
            + idle
        while i < len(prompts) and arrival[i] <= clock:
            states.append(engine.submit(Request(
                prompt=tuple(prompts[i]), max_new_tokens=int(gens[i]))))
            i += 1
        engine.step()
    wall = max(time.time() - t0, 1e-9)
    toks = sum(len(st.tokens) for st in states)
    lat: list[float] = []
    for st in states:
        ts = [st.arrival_t] + st.token_times
        lat += [b - a for a, b in zip(ts, ts[1:])]
    occ = ((engine.stats["occupancy_sum"] - occ0)
           / max(engine.stats["device_steps"] - dev0, 1))
    return toks / wall, lat, occ


def smoke_run(print_fn=print) -> dict:
    from repro.launch.serve import Server

    arrival, plens, gens = make_workload()
    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 1)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=int(L)).tolist()
               for L in plens]
    engine = server.engine(n_slots=SLOTS, fresh=True, prefill_bucket=8,
                           step_horizon=HORIZON)

    # warmup pass: compile the static scans (one per batch shape), the
    # engine step, and the admit-prefill buckets
    _run_static(server, prompts, gens)
    _run_engine(engine, prompts, gens, arrival)

    static_tok_s, static_lat = _run_static(server, prompts, gens)
    engine_tok_s, engine_lat, occ = _run_engine(engine, prompts, gens,
                                                arrival)
    r = {
        "static_tok_s": static_tok_s,
        "engine_tok_s": engine_tok_s,
        "speedup": engine_tok_s / max(static_tok_s, 1e-9),
        "static_latency": _pcts(static_lat),
        "engine_latency": _pcts(engine_lat),
        "engine_occupancy": occ,
    }
    print_fn(f"serving_smoke,static_tok_s={static_tok_s:.1f},"
             f"engine_tok_s={engine_tok_s:.1f},speedup={r['speedup']:.2f}x,"
             f"engine_p50={r['engine_latency']['p50_ms']:.1f}ms,"
             f"engine_p99={r['engine_latency']['p99_ms']:.1f}ms,"
             f"static_p50={r['static_latency']['p50_ms']:.1f}ms,"
             f"occupancy={occ:.2f}  (CPU-indicative)")
    return r


def run(print_fn=print, smoke: bool = True,
        out_path: str = "BENCH_serving.json") -> dict:
    arrival, plens, gens = make_workload()
    results: dict = {
        "workload": {"n_requests": N_REQ, "slots": SLOTS,
                     "arrival_steps": [int(a) for a in arrival],
                     "prompt_lens": [int(p) for p in plens],
                     "gen_lens": [int(g) for g in gens]},
    }
    m = modeled_slot_steps(arrival, gens)
    results["modeled"] = m
    modeled_ok = m["speedup"] >= 1.5
    results["modeled_speedup_ok"] = modeled_ok
    print_fn(f"serving_model,static_steps={m['static_steps']},"
             f"engine_steps={m['engine_steps']},"
             f"speedup={m['speedup']:.2f}x,"
             f"occupancy={m['engine_occupancy']:.2f}"
             f"(vs{m['static_occupancy']:.2f}),"
             f"{'PASS' if modeled_ok else 'FAIL'}")

    if smoke:
        s = smoke_run(print_fn)
        results["smoke"] = s
        # the headline claim, recorded in the artifact; the CI gate
        # (smoke_not_regressed) applies wall-clock slack
        smoke_ok = s["speedup"] >= 1.5
        results["smoke_speedup_ok"] = smoke_ok
        results["smoke_not_regressed"] = s["speedup"] >= 1.5 * SMOKE_SLACK
        print_fn(f"serving_check,engine_ge_1.5x_smoke,"
                 f"{'PASS' if smoke_ok else 'FAIL'}")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print_fn(f"serving_bench,wrote={out_path}")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--no-smoke", action="store_true",
                   help="skip the tiny-model wall-clock section")
    p.add_argument("--out", default="BENCH_serving.json")
    args = p.parse_args(argv)
    r = run(smoke=not args.no_smoke, out_path=args.out)
    ok = r["modeled_speedup_ok"] and r.get("smoke_speedup_ok", True)
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())

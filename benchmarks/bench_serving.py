"""Serving benchmark: continuous-batching engine vs the static batcher,
plus paged-vs-slot KV allocation under a fixed cache budget.

The engine's claim is system-level: the same kernels, the same per-step
cost, but no idle-slot work — a retired row's slot is reused immediately
instead of burning lockstep steps until the longest batchmate finishes.
Paged KV extends the claim to memory: under the SAME cache byte budget,
block-granular allocation admits strictly more concurrent short requests
than fixed max_len slot rows (a short request reserves its own worst-case
blocks, not a whole row) — which is how ABQ's 2.7x KV compression turns
into concurrency instead of stranded cache tail.
Measurements, written to ``BENCH_serving.json`` so the serving
trajectory is tracked PR over PR:

1. **Modeled slot-step account** (deterministic, the CI gate): a
   step-granular simulation of the same Poisson-arrival workload under
   both policies. The static batcher decodes batches of ``SLOTS`` requests
   in arrival order, every batch running to its longest member's budget
   (idle-slot steps are the waste); the engine admits arrivals into free
   slots between steps and retires rows at their own budgets. Per-step
   device cost is identical (same batch width, same compiled step), so the
   throughput ratio is the step-count ratio. Gate: **>= 1.5x**. Arrivals
   are charged to the engine (it waits for them) and granted to the static
   batcher for free — the model is conservative.

2. **Smoke wall-clock** (CPU, tiny model): the same workload driven
   through `Server.generate` (static) and `repro.serving.Engine`
   (continuous), reporting throughput tok/s, p50/p99 per-token latency,
   and mean slot occupancy. The engine pays a real host sync per step
   (the static scan pays one per call) and still must clear >= 1.5x.

3. **Paged-vs-slot admission** (deterministic model + real-engine smoke):
   the same byte budget is handed to both allocators (slot rows:
   ``budget // max_len`` rows; paged: ``budget // block_size`` blocks)
   and a short-request-heavy workload is admitted greedily. Gates: paged
   peak concurrency **strictly greater** than slot rows (modeled account,
   in `run.py --check`), the paged engine's observed ``peak_running``
   strictly exceeding the slot engine's in the smoke (step-count-
   deterministic, not wall-clock), and — off-TPU only — bitwise-equal
   outputs (both engines run identical jnp attention math there; on TPU
   the two paths pick different attention tile sizes, so equality is
   numerical, not bitwise).

4. **Chunked+paged long prompts** (deterministic smoke): the same long
   prompts through chunked+paged, chunked slot-row, and one-shot engines —
   paging must be invisible to the chunked math (token equality off-TPU),
   the chunked streams must match one-shot prefill on this workload, and
   the chunk accounting and pool drain are gated too
   (`chunked_paged_smoke_run`; gate ``serving_chunked_paged``).

5. **Optimistic overcommit vs worst-case reservation** (deterministic
   model + real-engine exactness check; both run even with
   ``--no-smoke``): on a heavy-tailed workload — every request *claims*
   a long budget, most stop far short — the reservation baseline's peak
   concurrency is bounded by the claims while optimistic admission with
   preempt-and-requeue is bounded by tokens actually written. Gates:
   modeled optimistic peak **>= 1.3x** the reservation baseline
   (``serving_overcommit_concurrency``), and a churning real engine
   (undersized pool + ``overcommit=True``, preemptions forced) must emit
   **bitwise identical** token streams to a sequential no-churn engine
   (``serving_preempt_exactness`` — preemption is invisible in outputs).

6. **Metrics overhead + snapshot schema** (runs even with ``--no-smoke``,
   so ``run.py --check`` gates it): the same workload through a
   metrics-on and a metrics-off engine. Outputs must be bitwise identical
   (telemetry is a host-side observer — it must never perturb the device
   computation), the metrics-on min-of-N drain must stay within
   ``METRICS_OVERHEAD_TOL`` of metrics-off, and the snapshot must satisfy
   `repro.serving.metrics.check_snapshot` (stable operator-facing schema).
   Gates: ``serving_metrics_overhead``, ``serving_metrics_schema``.

7. **Fault chaos** (seeded, deterministic; runs even with ``--no-smoke``):
   a `repro.serving.FaultSchedule` injects pool exhaustion, NaN logits,
   clock jumps, submit storms and cancels into an overcommitted paged
   engine while `repro.serving.run_chaos` audits block-pool conservation,
   all-requests-terminal, and the metrics terminal-reason conservation
   identity after every step. Gate: ``serving_fault_chaos`` (zero
   violations).

Usage: PYTHONPATH=src python -m benchmarks.bench_serving [--no-smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

SLOTS = 8
N_REQ = 32
SEED = 3
MAX_LEN = 128
HORIZON = 8  # engine multi-step horizon (tokens per jitted step)
# paged-vs-slot scenario: same cache budget (SLOTS * MAX_LEN tokens) handed
# to both allocators; the paged engine runs more rows and lets the block
# pool, not the row count, bound admission. PAGED_BUCKET/PAGED_HORIZON are
# shared by the deterministic admission model and the real smoke engines so
# the two accountings cannot drift apart.
KV_BLOCK = 16
PAGED_SLOTS = 16
N_SHORT = 24
PAGED_BUCKET = 8
PAGED_HORIZON = 1
# chunked+paged scenario: long prompts fed one chunk per step over the
# paged pool (the composition the prefix-clamped attend_chunk unlocked)
CHUNK_PREFILL = 16
N_LONG = 6
LONG_PROMPT = 48
ARRIVAL_SCALE = 1.0  # mean inter-arrival, in decode steps (Poisson process)
# CPU wall-clock slack for the smoke gate in run.py (containers are noisy;
# the modeled slot-step account is the deterministic gate — same convention
# as bench_decode_attn's SMOKE_SLACK)
SMOKE_SLACK = 0.6
# telemetry must be ~free: metrics-on min-of-N wall-clock within 5% of
# metrics-off (min-of-N because container noise is one-sided — slowdowns,
# never speedups; the drain is a few hundred ms — see
# metrics_overhead_run — so N=5 pushes the min well under the
# container's few-ms jitter)
METRICS_OVERHEAD_TOL = 0.05
METRICS_REPS = 8
# overcommit scenario: heavy-tailed claims (every request *claims* a long
# budget, most stop far short of it) against a pool sized so worst-case
# reservation serializes. Optimistic admission must model >= 1.3x the
# reservation baseline's peak concurrency.
N_HEAVY = 24
HEAVY_CLAIM = 64
# sized so the sim also crosses the eviction path: all N_HEAVY prefill
# extents fit exactly, the first long request's growth forces a preempt
OVERCOMMIT_BUDGET = 3 * SLOTS * MAX_LEN // 8
OVERCOMMIT_GAIN_MIN = 1.3


def make_workload(seed: int = SEED, n: int = N_REQ):
    """(arrival_step, prompt_len, gen_len) per request. Prompt lengths are
    bucket-aligned (8/16/24; the engine's default prefill bucket); the
    generation budgets are heavy-tailed — mostly short (2..12), a quarter
    long (60..90), the realistic serving mix. Raggedness is what the
    static batcher pays for (every batch runs to its longest member) and
    the engine does not."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(scale=ARRIVAL_SCALE, size=n)
    arrival = np.floor(np.cumsum(inter) - inter[0]).astype(int)
    long_mask = rng.random(n) < 0.25
    gens = np.where(long_mask, rng.integers(60, 91, size=n),
                    rng.integers(2, 13, size=n)).astype(int)
    plens = (rng.integers(1, 4, size=n) * 8).astype(int)
    return arrival, plens, gens


# ---------------------------------------------------------------------------
# 1) modeled slot-step account (the deterministic gate)
# ---------------------------------------------------------------------------


def modeled_slot_steps(arrival, gens, slots: int = SLOTS,
                       horizon: int = HORIZON) -> dict:
    """Device token-steps under both policies (per-step device cost is
    identical — same batch width, same compiled step — so the throughput
    ratio is the token-step ratio). The engine admits/retires at
    ``horizon``-block granularity: a row finishing mid-block wastes the
    tail of that block, which is charged to the engine."""
    gens = list(map(int, gens))
    static_steps = sum(max(gens[i:i + slots])
                       for i in range(0, len(gens), slots))
    useful = sum(gens)

    queue: list[int] = []
    active: list[int] = []
    t = inner_steps = calls = 0
    occ_sum = 0.0
    i, done = 0, 0
    n = len(gens)
    while done < n:
        while i < n and arrival[i] <= t:
            queue.append(gens[i])
            i += 1
        while queue and len(active) < slots:
            active.append(queue.pop(0))
        if active:
            inner_steps += horizon
            calls += 1
            occ_sum += len(active) / slots
            active = [g - horizon for g in active]
            done += sum(1 for g in active if g <= 0)
            active = [g for g in active if g > 0]
            t += horizon
        else:
            t += 1  # idle: waiting on the arrival process

    static_occ = useful / (static_steps * slots)
    return {
        "useful_tokens": useful,
        "static_steps": static_steps,
        "engine_steps": inner_steps,  # device token-steps (incl. block tails)
        "engine_calls": calls,
        "speedup": static_steps / max(inner_steps, 1),
        "engine_occupancy": occ_sum / max(calls, 1),
        "static_occupancy": static_occ,
    }


# ---------------------------------------------------------------------------
# 1b) paged-vs-slot admission under one cache budget
# ---------------------------------------------------------------------------


def make_short_workload(seed: int = SEED + 7, n: int = N_SHORT):
    """The workload slot-rows are worst at: uniformly short requests
    (8-token prompts, 4..8 generated tokens) against a max_len sized for
    the occasional long one. Every request needs ~1 KV block but a slot
    row reserves all MAX_LEN positions."""
    rng = np.random.default_rng(seed)
    plens = np.full(n, 8, int)
    gens = rng.integers(4, 9, size=n).astype(int)
    return plens, gens


def modeled_paged_admission(plens, gens, *, budget_tokens: int = SLOTS * MAX_LEN,
                            max_len: int = MAX_LEN, block: int = KV_BLOCK,
                            bucket: int = PAGED_BUCKET,
                            horizon: int = PAGED_HORIZON) -> dict:
    """Peak admissible concurrency under one cache byte budget.

    Slot rows: every request reserves a full ``max_len`` row —
    concurrency = budget // max_len regardless of request size. Paged:
    a request reserves ceil(need / block) blocks where ``need`` mirrors
    the engine's worst-case accounting (block-rounded prefill extent vs
    prompt + budget + horizon tail); greedy FIFO admission packs blocks
    until the pool is dry. The deterministic CI gate: paged concurrency
    must be STRICTLY greater on the short-request workload."""
    def need(L, g):
        extent = -(-int(L) // bucket) * bucket
        extent = -(-extent // block) * block
        return max(extent, int(L) + int(g) + horizon - 1)

    needs = [need(L, g) for L, g in zip(plens, gens)]
    slot_cap = budget_tokens // max_len
    slot_peak = min(len(needs), slot_cap)
    # tokens a slot row strands per admitted short request
    stranded = [max_len - n_ for n_ in needs[:slot_peak]]

    total_blocks = budget_tokens // block
    used = 0
    paged_peak = 0
    for n_ in needs:
        nb = -(-n_ // block)
        if used + nb > total_blocks:
            break
        used += nb
        paged_peak += 1
    return {
        "budget_tokens": budget_tokens,
        "block_size": block,
        "slot_peak_concurrency": slot_peak,
        "paged_peak_concurrency": paged_peak,
        "slot_stranded_tokens": int(sum(stranded)),
        "paged_reserved_blocks": used,
        "concurrency_gain": paged_peak / max(slot_peak, 1),
    }


def paged_smoke_run(print_fn=print) -> dict:
    """Real engines, same quantized model, same cache byte budget: the
    slot-row engine (SLOTS rows x MAX_LEN) vs the paged engine
    (PAGED_SLOTS rows, pool = SLOTS * MAX_LEN tokens of KV_BLOCK-token
    blocks). Everything gated here is step-count-deterministic (peak
    concurrent running rows, device steps) — wall-clock is reported for
    context only. Output equality is additionally gated off-TPU, where
    both engines run the identical jnp attention math; on TPU the two
    paths legitimately pick different attention tile sizes (contiguous
    block_s vs page-divisor block_s), and a different online-softmax
    partition is numerically — not bitwise — equivalent."""
    import jax

    from repro.launch.serve import Server

    plens, gens = make_short_workload()
    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 8)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=int(L)).tolist()
               for L in plens]

    def drain(engine):
        from repro.serving import Request

        t0 = time.perf_counter()
        states = [engine.submit(Request(prompt=tuple(p),
                                        max_new_tokens=int(g)))
                  for p, g in zip(prompts, gens)]
        engine.run()
        wall = max(time.perf_counter() - t0, 1e-9)
        outs = [st.output() for st in states]
        return {
            "peak_running": engine.stats["peak_running"],
            "device_steps": engine.stats["device_steps"],
            "tok_s": sum(len(o) for o in outs) / wall,
        }, outs

    slot_stats, slot_outs = drain(
        server.engine(n_slots=SLOTS, fresh=True,
                      prefill_bucket=PAGED_BUCKET,
                      step_horizon=PAGED_HORIZON))
    paged_eng = server.engine(
        n_slots=PAGED_SLOTS, fresh=True, prefill_bucket=PAGED_BUCKET,
        step_horizon=PAGED_HORIZON,
        kv_block_size=KV_BLOCK, kv_pool_tokens=SLOTS * MAX_LEN)
    paged_stats, paged_outs = drain(paged_eng)
    match_required = jax.default_backend() != "tpu"
    r = {
        "slot": slot_stats,
        "paged": paged_stats,
        "pool": paged_eng.pool.stats(),
        "outputs_match": slot_outs == paged_outs,
        "outputs_match_required": match_required,
        "concurrency_ok": paged_stats["peak_running"]
        > slot_stats["peak_running"],
    }
    ok = r["concurrency_ok"] and (r["outputs_match"] or not match_required)
    print_fn(f"serving_paged_smoke,slot_peak={slot_stats['peak_running']},"
             f"paged_peak={paged_stats['peak_running']},"
             f"slot_steps={slot_stats['device_steps']},"
             f"paged_steps={paged_stats['device_steps']},"
             f"outputs_match={r['outputs_match']},"
             f"{'PASS' if ok else 'FAIL'}")
    return r


def chunked_paged_smoke_run(print_fn=print) -> dict:
    """Long-prompt chunked prefill OVER the paged pool — the combination
    the prefix-clamped `attend_chunk` lifted the engine restriction for.
    Three real engines on the same long-prompt workload: chunked+paged,
    chunked slot-row, and one-shot slot-row. Gates (all deterministic):

    * chunked+paged outputs == chunked slot-row outputs (paging must be
      invisible to the chunked math; off-TPU both run the identical jnp
      chunk attention, so token equality is exact — on TPU different
      block_s picks make it numerical, so the gate applies off-TPU only,
      same convention as `paged_smoke_run`);
    * chunked+paged outputs == one-shot outputs (chunk numerics track the
      decode regime closely enough to preserve greedy streams on this
      pinned workload);
    * the long prompts actually went through the chunked path
      (``prefill_chunks`` matches the ceil(L/chunk) account) and the pool
      drained (free-on-retire).
    """
    import jax

    from repro.launch.serve import Server

    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 9)
    prompts = [rng.integers(0, server.cfg.vocab_size,
                            size=LONG_PROMPT).tolist()
               for _ in range(N_LONG)]
    gens = rng.integers(4, 9, size=N_LONG).astype(int)

    def drain(engine):
        from repro.serving import Request

        states = [engine.submit(Request(prompt=tuple(p),
                                        max_new_tokens=int(g)))
                  for p, g in zip(prompts, gens)]
        engine.run()
        return [st.output() for st in states], dict(engine.stats)

    kw = dict(fresh=True, n_slots=4, prefill_bucket=PAGED_BUCKET,
              step_horizon=PAGED_HORIZON)
    cp_eng = server.engine(prefill_chunk=CHUNK_PREFILL,
                           kv_block_size=KV_BLOCK, **kw)
    cp_outs, cp_stats = drain(cp_eng)
    chunk_outs, chunk_stats = drain(
        server.engine(prefill_chunk=CHUNK_PREFILL, **kw))
    shot_outs, _ = drain(server.engine(**kw))

    expected_chunks = N_LONG * (-(-LONG_PROMPT // CHUNK_PREFILL))
    match_required = jax.default_backend() != "tpu"
    r = {
        "prefill_chunks": cp_stats["prefill_chunks"],
        "expected_chunks": expected_chunks,
        "paged_matches_slot_chunked": cp_outs == chunk_outs,
        "matches_one_shot": cp_outs == shot_outs,
        "outputs_match_required": match_required,
        "pool_drained": cp_eng.pool.used_blocks == 0,
        "chunked_ran": (cp_stats["prefill_chunks"] == expected_chunks
                        and chunk_stats["prefill_chunks"]
                        == expected_chunks),
    }
    ok = (r["chunked_ran"] and r["pool_drained"]
          and ((r["paged_matches_slot_chunked"] and r["matches_one_shot"])
               or not match_required))
    print_fn(f"serving_chunked_paged,chunks={r['prefill_chunks']}"
             f"/{expected_chunks},"
             f"paged_eq_slot={r['paged_matches_slot_chunked']},"
             f"eq_one_shot={r['matches_one_shot']},"
             f"pool_drained={r['pool_drained']},"
             f"{'PASS' if ok else 'FAIL'}")
    r["ok"] = ok
    return r


# ---------------------------------------------------------------------------
# 1c) optimistic overcommit vs worst-case reservation
# ---------------------------------------------------------------------------


def make_heavy_tailed_workload(seed: int = SEED + 11, n: int = N_HEAVY):
    """The workload worst-case reservation is worst at: every request
    *claims* ``HEAVY_CLAIM`` new tokens (the API budget), but actual
    generation is heavy-tailed — most stop within a handful of tokens
    (EOS), only ~20% run the full claim. Reservation admission pays for
    the claim; optimistic admission pays for the tokens written. Prompt
    extents land on a block boundary, so a row's first generated token
    already needs a fresh block — growth races release from step one
    and the model's eviction path is actually exercised."""
    rng = np.random.default_rng(seed)
    plens = np.full(n, 14, int)
    claims = np.full(n, HEAVY_CLAIM, int)
    long_mask = rng.random(n) < 0.2
    actual = np.where(long_mask, claims, rng.integers(2, 9, size=n))
    return plens, claims, actual.astype(int)


def modeled_overcommit_concurrency(
        plens, claims, actual, *, budget_tokens: int = OVERCOMMIT_BUDGET,
        block: int = KV_BLOCK, bucket: int = PAGED_BUCKET,
        horizon: int = PAGED_HORIZON) -> dict:
    """Peak concurrency under one block budget, reservation vs optimistic.

    Baseline (worst-case reservation): each request reserves
    ceil(need(prompt, claim) / block) blocks up front — the engine's
    conservative paged admission — packed greedily FIFO until the pool is
    dry. Optimistic: a step-granular simulation where a row holds blocks
    only for tokens actually written; when a row's next token needs a
    block and none is free, the youngest row is evicted (its blocks
    return, it requeues at its original position and recomputes) — the
    engine's preempt-and-requeue policy. The deterministic CI gate:
    optimistic peak concurrency >= ``OVERCOMMIT_GAIN_MIN`` x baseline."""
    def need(L, g):
        extent = -(-int(L) // bucket) * bucket
        extent = -(-extent // block) * block
        return max(extent, int(L) + int(g) + horizon - 1)

    def blocks(tokens):
        return -(-int(tokens) // block)

    total = budget_tokens // block

    used = reserved_peak = 0
    for L, c in zip(plens, claims):
        nb = blocks(need(L, c))
        if used + nb > total:
            break
        used += nb
        reserved_peak += 1

    # optimistic step sim: FIFO admission on the prefill extent, one token
    # per active row per step, evict-youngest on allocation failure
    n = len(plens)
    todo = list(range(n))
    pos: dict[int, int] = {}   # id -> tokens held (admission order = age)
    done: set[int] = set()
    free = total
    peak = evictions = recompute_tokens = 0
    while len(done) < n:
        while todo:
            i = todo[0]
            ext = -(-int(plens[i]) // bucket) * bucket
            if blocks(ext) > free:
                break
            todo.pop(0)
            pos[i] = ext
            free -= blocks(ext)
        peak = max(peak, len(pos))
        for i in list(pos):
            if i not in pos:
                continue  # evicted mid-step by an earlier row's growth
            target = int(plens[i]) + int(actual[i])
            if pos[i] >= target:
                free += blocks(pos[i])
                del pos[i]
                done.add(i)
                continue
            if blocks(pos[i] + 1) > blocks(pos[i]) and free == 0:
                victim = max(pos)  # youngest admitted (FIFO ids)
                free += blocks(pos[victim])
                recompute_tokens += pos[victim]
                del pos[victim]
                todo.insert(0, victim)
                evictions += 1
                if victim == i:
                    continue
            pos[i] += 1
            if blocks(pos[i]) > blocks(pos[i] - 1):
                free -= 1

    return {
        "budget_tokens": budget_tokens,
        "total_blocks": total,
        "reserved_peak_concurrency": reserved_peak,
        "optimistic_peak_concurrency": peak,
        "evictions": evictions,
        "recompute_tokens": recompute_tokens,
        "concurrency_gain": peak / max(reserved_peak, 1),
        "gain_min": OVERCOMMIT_GAIN_MIN,
    }


def preempt_exactness_run(print_fn=print) -> dict:
    """Preemption must be invisible in the token streams: the same
    requests through a churning engine (undersized pool + overcommit, so
    rows are evicted mid-generation and resumed by replay) and through a
    sequential no-churn engine (one slot, ample pool — each request runs
    alone). Outputs must be **bitwise identical** — greedy and sampled
    streams both — and the churn engine must have actually preempted
    (otherwise the gate is vacuous). Deterministic, so it runs even with
    ``--no-smoke`` and gates ``run.py --check``
    (``serving_preempt_exactness``)."""
    from repro.launch.serve import Server
    from repro.serving import Request, SamplingParams

    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 12)
    reqs = []
    for i in range(8):
        p = tuple(int(t) for t in
                  rng.integers(0, server.cfg.vocab_size,
                               size=int(rng.integers(8, 21))))
        sampling = SamplingParams(greedy=False, temperature=0.8, top_k=8,
                                  seed=200 + i) if i % 3 == 0 \
            else SamplingParams()
        reqs.append(Request(prompt=p, max_new_tokens=int(rng.integers(8, 15)),
                            sampling=sampling))

    def drain(engine):
        states = [engine.submit(r) for r in reqs]
        engine.run()
        return [st.output() for st in states]

    kw = dict(fresh=True, prefill_bucket=PAGED_BUCKET,
              step_horizon=PAGED_HORIZON, prefill_chunk=PAGED_BUCKET,
              kv_block_size=KV_BLOCK)
    churn_eng = server.engine(n_slots=4, kv_pool_tokens=3 * KV_BLOCK,
                              overcommit=True, **kw)
    churn_outs = drain(churn_eng)
    churn_stats = dict(churn_eng.stats)
    solo_outs = drain(server.engine(n_slots=1,
                                    kv_pool_tokens=8 * KV_BLOCK, **kw))
    r = {
        "outputs_match": churn_outs == solo_outs,
        "preemptions": churn_stats["preemptions"],
        "replayed_tokens": churn_stats["replayed_tokens"],
        "pool": churn_eng.pool.stats(),
        "churned": churn_stats["preemptions"] > 0,
    }
    r["ok"] = r["outputs_match"] and r["churned"]
    print_fn(f"serving_preempt_exactness,"
             f"preemptions={r['preemptions']},"
             f"replayed={r['replayed_tokens']},"
             f"outputs_match={r['outputs_match']},"
             f"{'PASS' if r['ok'] else 'FAIL'}")
    return r


# ---------------------------------------------------------------------------
# 1d) telemetry: zero-interference + overhead + snapshot schema
# ---------------------------------------------------------------------------


def metrics_overhead_run(print_fn=print, reps: int = METRICS_REPS) -> dict:
    """Telemetry must be free: the same workload through a metrics-on and
    a metrics-off engine (same quantized model, paged pool, chunked
    prefill — the fully-loaded configuration, so every hook fires). The
    workload is a longer variant of the short one (twice the requests,
    ~20-token budgets) so each drain is a few hundred ms — long enough
    that the container's few-ms scheduling jitter cannot swing the
    relative comparison across the tolerance.

    Gated here, and by ``run.py --check`` (this section runs even with
    ``--no-smoke``):

    * **bitwise outputs** (deterministic): the metrics facade is a
      host-side observer — token streams must be identical on vs off;
    * **snapshot schema** (deterministic): the metrics-on snapshot passes
      `check_snapshot` (operators script against this dict — drift is an
      API break);
    * **overhead** (wall-clock): min-of-``reps`` drain time on within
      ``METRICS_OVERHEAD_TOL`` of off. Each engine instance carries its
      own jitted step, so both get their own warmup drain before timing.
    """
    from repro.launch.serve import Server
    from repro.serving import Request
    from repro.serving.metrics import check_snapshot

    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 10)
    plens = np.full(N_SHORT * 2, 8, int)
    gens = rng.integers(16, 25, size=N_SHORT * 2).astype(int)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=int(L)).tolist()
               for L in plens]

    def drain(engine):
        t0 = time.perf_counter()
        states = [engine.submit(Request(prompt=tuple(p),
                                        max_new_tokens=int(g)))
                  for p, g in zip(prompts, gens)]
        engine.run()
        return time.perf_counter() - t0, [st.output() for st in states]

    kw = dict(n_slots=SLOTS, fresh=True, prefill_bucket=PAGED_BUCKET,
              step_horizon=PAGED_HORIZON, prefill_chunk=CHUNK_PREFILL,
              kv_block_size=KV_BLOCK, kv_pool_tokens=SLOTS * MAX_LEN)
    eng_on = server.engine(metrics=True, **kw)
    eng_off = server.engine(metrics=False, **kw)
    drain(eng_on)   # warmup: per-instance jitted step + admit buckets
    drain(eng_off)
    on_s, off_s = [], []
    outs_on = outs_off = None
    for _ in range(reps):
        dt, outs_on = drain(eng_on)
        on_s.append(dt)
        dt, outs_off = drain(eng_off)
        off_s.append(dt)
    snap = eng_on.metrics.snapshot()
    schema_problems = check_snapshot(snap)
    overhead = min(on_s) / max(min(off_s), 1e-9) - 1.0
    r = {
        "on_s": min(on_s),
        "off_s": min(off_s),
        "overhead_frac": overhead,
        "tolerance": METRICS_OVERHEAD_TOL,
        "outputs_match": outs_on == outs_off,
        "schema_problems": schema_problems,
        "snapshot_counters": dict(snap["counters"]),
        "overhead_ok": overhead <= METRICS_OVERHEAD_TOL,
        "schema_ok": not schema_problems,
    }
    r["ok"] = r["overhead_ok"] and r["outputs_match"]
    print_fn(f"serving_metrics_overhead,on_s={r['on_s']:.3f},"
             f"off_s={r['off_s']:.3f},overhead={overhead * 100:.1f}%,"
             f"outputs_match={r['outputs_match']},"
             f"schema_problems={len(schema_problems)},"
             f"{'PASS' if r['ok'] and r['schema_ok'] else 'FAIL'}")
    return r


# ---------------------------------------------------------------------------
# 1e) fault chaos: seeded injection must not break engine invariants
# ---------------------------------------------------------------------------


def fault_chaos_run(print_fn=print) -> dict:
    """Seeded chaos through the real engine (deterministic, so it runs
    even with ``--no-smoke`` and gates ``run.py --check`` as
    ``serving_fault_chaos``): a `repro.serving.FaultSchedule` injects
    pool exhaustion, NaN logits, clock jumps, submit storms and cancels
    into an overcommitted paged engine while `repro.serving.run_chaos`
    audits the robustness invariants after every step — block-pool
    conservation (`BlockPool.check`), every request (original and
    storm-injected) reaching a terminal state, and the metrics
    terminal-reason conservation identity. The same driver backs the
    pytest chaos property test, so CI and the suite judge one
    contract."""
    from repro.launch.serve import Server
    from repro.serving import (FakeClock, FaultSchedule, Request,
                               SamplingParams, run_chaos)

    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 13)

    def rand_request(r, i=None):
        # doubles as the schedule's storm factory (called with the
        # schedule's own rng, i=None → plain greedy request)
        p = tuple(int(t) for t in
                  r.integers(0, server.cfg.vocab_size,
                             size=int(r.integers(4, 13))))
        sampling = SamplingParams(greedy=False, temperature=0.8, top_k=8,
                                  seed=300 + i) \
            if i is not None and i % 3 == 0 else SamplingParams()
        return Request(prompt=p, max_new_tokens=int(r.integers(4, 11)),
                       deadline_s=25.0
                       if i is not None and i % 4 == 0 else None,
                       sampling=sampling)

    reqs = [rand_request(rng, i) for i in range(10)]
    clock = FakeClock()
    schedule = FaultSchedule(
        SEED + 13, nan_rate=0.08, exhaust_rate=0.10, clock_rate=0.10,
        clock_jump_s=5.0, storm_rate=0.10, storm_size=2, cancel_rate=0.15,
        max_faults=12, request_factory=rand_request, clock=clock)
    eng = server.engine(
        n_slots=4, fresh=True, prefill_bucket=PAGED_BUCKET,
        step_horizon=PAGED_HORIZON, prefill_chunk=PAGED_BUCKET,
        kv_block_size=KV_BLOCK, kv_pool_tokens=8 * KV_BLOCK,
        overcommit=True, clock=clock, fault_hook=schedule)
    res = run_chaos(eng, reqs, schedule, max_steps=2000)
    term = eng.metrics.snapshot()["terminal"]
    kinds: dict = {}
    for rec in schedule.log:
        kinds[rec["kind"]] = kinds.get(rec["kind"], 0) + 1
    r = {
        "n_requests": len(res["states"]),
        "steps": res["steps"],
        "faults": kinds,
        "terminal": term,
        "violations": res["violations"],
        "ok": not res["violations"],
    }
    print_fn(f"serving_fault_chaos,requests={r['n_requests']},"
             f"steps={r['steps']},faults={sum(kinds.values())},"
             f"finished={term['finished']},timed_out={term['timed_out']},"
             f"cancelled={term['cancelled']},failed={term['failed']},"
             f"violations={len(res['violations'])},"
             f"{'PASS' if r['ok'] else 'FAIL'}")
    return r


# ---------------------------------------------------------------------------
# 2) smoke wall-clock (tiny model, CPU-indicative)
# ---------------------------------------------------------------------------


# percentile math lives in the serving telemetry core now
# (repro.serving.metrics.pcts_ms — same linear interpolation as
# np.percentile, so historical BENCH_serving.json values stay comparable;
# imported inside the smoke functions like every other repro import so the
# bench module stays jax-free at import time)


def _run_static(server, prompts, gens):
    """Batches of SLOTS in arrival order, lockstep to the batch max; a
    token's latency is the whole batch wall (the scan only surfaces tokens
    at the end). Useful tokens exclude the lockstep overrun rows."""
    t0 = time.perf_counter()
    lat: list[float] = []
    toks = 0
    for s in range(0, len(prompts), SLOTS):
        bp, bg = prompts[s:s + SLOTS], gens[s:s + SLOTS]
        tb = time.perf_counter()
        server.generate(bp, max_new_tokens=int(max(bg)))
        dt = time.perf_counter() - tb
        for g in bg:
            toks += int(g)
            lat += [dt] * int(g)
    return toks / max(time.perf_counter() - t0, 1e-9), lat


def _run_engine(engine, prompts, gens, arrival):
    """Poisson arrivals on the token-step clock (a horizon block advances
    it by H, an idle poll by 1 — the same clock the static batcher's steps
    tick on); per-token latency is first token from submit, then
    inter-token gaps (tokens stream per block)."""
    from repro.serving import Request

    occ0 = engine.stats["occupancy_sum"]
    dev0 = engine.stats["device_steps"]
    base_steps = engine.stats["steps"]
    t0 = time.perf_counter()
    states, i = [], 0
    while i < len(prompts) or engine.has_work():
        idle = (engine.stats["steps"] - base_steps) \
            - (engine.stats["device_steps"] - dev0)
        clock = (engine.stats["device_steps"] - dev0) * engine.step_horizon \
            + idle
        while i < len(prompts) and arrival[i] <= clock:
            states.append(engine.submit(Request(
                prompt=tuple(prompts[i]), max_new_tokens=int(gens[i]))))
            i += 1
        engine.step()
    wall = max(time.perf_counter() - t0, 1e-9)
    toks = sum(len(st.tokens) for st in states)
    lat: list[float] = []
    for st in states:
        # submit_t and token_times share the engine's monotonic clock, so
        # the first-token gap can never come out negative (arrival_t is
        # wall clock, for logs only)
        ts = [st.submit_t] + st.token_times
        lat += [b - a for a, b in zip(ts, ts[1:])]
    occ = ((engine.stats["occupancy_sum"] - occ0)
           / max(engine.stats["device_steps"] - dev0, 1))
    return toks / wall, lat, occ


def smoke_run(print_fn=print) -> dict:
    from repro.launch.serve import Server
    from repro.serving.metrics import pcts_ms

    arrival, plens, gens = make_workload()
    server = Server(arch="qwen3-4b", smoke=True, w_bits=2, max_len=MAX_LEN)
    rng = np.random.default_rng(SEED + 1)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=int(L)).tolist()
               for L in plens]
    engine = server.engine(n_slots=SLOTS, fresh=True, prefill_bucket=8,
                           step_horizon=HORIZON)

    # warmup pass: compile the static scans (one per batch shape), the
    # engine step, and the admit-prefill buckets
    _run_static(server, prompts, gens)
    _run_engine(engine, prompts, gens, arrival)

    static_tok_s, static_lat = _run_static(server, prompts, gens)
    engine_tok_s, engine_lat, occ = _run_engine(engine, prompts, gens,
                                                arrival)
    # request-level percentiles straight off the engine's own telemetry
    # (accumulated over warmup + measured pass; ms to match the rest of
    # the artifact)
    snap = engine.metrics.snapshot()
    r = {
        "static_tok_s": static_tok_s,
        "engine_tok_s": engine_tok_s,
        "speedup": engine_tok_s / max(static_tok_s, 1e-9),
        "static_latency": pcts_ms(static_lat),
        "engine_latency": pcts_ms(engine_lat),
        "engine_occupancy": occ,
        "engine_ttft_ms": {k: v * 1e3
                           for k, v in snap["latency_s"]["ttft"].items()
                           if k.startswith("p")},
        "engine_tpot_ms": {k: v * 1e3
                           for k, v in snap["latency_s"]["tpot"].items()
                           if k.startswith("p")},
        "engine_queue_wait_ms": {
            k: v * 1e3
            for k, v in snap["latency_s"]["queue_wait"].items()
            if k.startswith("p")},
    }
    print_fn(f"serving_smoke,static_tok_s={static_tok_s:.1f},"
             f"engine_tok_s={engine_tok_s:.1f},speedup={r['speedup']:.2f}x,"
             f"engine_p50={r['engine_latency']['p50_ms']:.1f}ms,"
             f"engine_p99={r['engine_latency']['p99_ms']:.1f}ms,"
             f"static_p50={r['static_latency']['p50_ms']:.1f}ms,"
             f"occupancy={occ:.2f}  (CPU-indicative)")
    return r


def run(print_fn=print, smoke: bool = True,
        out_path: str = "BENCH_serving.json") -> dict:
    arrival, plens, gens = make_workload()
    results: dict = {
        "workload": {"n_requests": N_REQ, "slots": SLOTS,
                     "arrival_steps": [int(a) for a in arrival],
                     "prompt_lens": [int(p) for p in plens],
                     "gen_lens": [int(g) for g in gens]},
    }
    m = modeled_slot_steps(arrival, gens)
    results["modeled"] = m
    modeled_ok = m["speedup"] >= 1.5
    results["modeled_speedup_ok"] = modeled_ok
    print_fn(f"serving_model,static_steps={m['static_steps']},"
             f"engine_steps={m['engine_steps']},"
             f"speedup={m['speedup']:.2f}x,"
             f"occupancy={m['engine_occupancy']:.2f}"
             f"(vs{m['static_occupancy']:.2f}),"
             f"{'PASS' if modeled_ok else 'FAIL'}")

    # paged-vs-slot KV allocation under one cache budget (deterministic)
    sp, sg = make_short_workload()
    pm = modeled_paged_admission(sp, sg)
    results["paged_modeled"] = pm
    paged_ok = (pm["paged_peak_concurrency"]
                > pm["slot_peak_concurrency"])
    results["paged_concurrency_ok"] = paged_ok
    print_fn(f"serving_paged_model,slot_peak={pm['slot_peak_concurrency']},"
             f"paged_peak={pm['paged_peak_concurrency']},"
             f"gain={pm['concurrency_gain']:.2f}x,"
             f"stranded_slot_tokens={pm['slot_stranded_tokens']},"
             f"{'PASS' if paged_ok else 'FAIL'}")

    # optimistic overcommit vs worst-case reservation (deterministic):
    # heavy-tailed claims, the scenario the preempt-and-requeue engine
    # exists for
    hp, hc, ha = make_heavy_tailed_workload()
    oc = modeled_overcommit_concurrency(hp, hc, ha)
    results["overcommit_modeled"] = oc
    oc_ok = oc["concurrency_gain"] >= OVERCOMMIT_GAIN_MIN
    results["overcommit_concurrency_ok"] = oc_ok
    print_fn(f"serving_overcommit_model,"
             f"reserved_peak={oc['reserved_peak_concurrency']},"
             f"optimistic_peak={oc['optimistic_peak_concurrency']},"
             f"gain={oc['concurrency_gain']:.2f}x,"
             f"evictions={oc['evictions']},"
             f"{'PASS' if oc_ok else 'FAIL'}")

    # preemption exactness (real engines, deterministic token equality):
    # runs even without smoke so --check catches a resume-replay
    # regression before it ships
    pe = preempt_exactness_run(print_fn)
    results["preempt_exactness"] = pe
    results["preempt_exactness_ok"] = pe["ok"]

    # telemetry gates run even without smoke: bitwise zero-interference
    # and the snapshot schema are deterministic, and --check (smoke=False)
    # must catch an instrumentation regression before it ships
    mo = metrics_overhead_run(print_fn)
    results["metrics_overhead"] = mo
    results["metrics_overhead_ok"] = mo["ok"]
    results["metrics_schema_ok"] = mo["schema_ok"]

    # fault chaos (seeded, deterministic — runs even without smoke so
    # --check gates the robustness invariants before they ship)
    fc = fault_chaos_run(print_fn)
    results["fault_chaos"] = fc
    results["fault_chaos_ok"] = fc["ok"]

    if smoke:
        ps = paged_smoke_run(print_fn)
        results["paged_smoke"] = ps
        results["paged_smoke_ok"] = (
            ps["concurrency_ok"]
            and (ps["outputs_match"] or not ps["outputs_match_required"]))
        cp = chunked_paged_smoke_run(print_fn)
        results["chunked_paged_smoke"] = cp
        results["chunked_paged_ok"] = cp["ok"]
        s = smoke_run(print_fn)
        results["smoke"] = s
        # the headline claim, recorded in the artifact; the CI gate
        # (smoke_not_regressed) applies wall-clock slack
        smoke_ok = s["speedup"] >= 1.5
        results["smoke_speedup_ok"] = smoke_ok
        results["smoke_not_regressed"] = s["speedup"] >= 1.5 * SMOKE_SLACK
        print_fn(f"serving_check,engine_ge_1.5x_smoke,"
                 f"{'PASS' if smoke_ok else 'FAIL'}")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print_fn(f"serving_bench,wrote={out_path}")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--no-smoke", action="store_true",
                   help="skip the tiny-model wall-clock section")
    p.add_argument("--out", default="BENCH_serving.json")
    args = p.parse_args(argv)
    r = run(smoke=not args.no_smoke, out_path=args.out)
    ok = (r["modeled_speedup_ok"] and r["paged_concurrency_ok"]
          and r["overcommit_concurrency_ok"] and r["preempt_exactness_ok"]
          and r["metrics_overhead_ok"] and r["metrics_schema_ok"]
          and r["fault_chaos_ok"]
          and r.get("smoke_speedup_ok", True)
          and r.get("paged_smoke_ok", True)
          and r.get("chunked_paged_ok", True))
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())

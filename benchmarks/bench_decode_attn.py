"""Decode-attention benchmark: Pallas flash-decoding vs the jnp int8 path.

Two measurements, written to ``BENCH_decode_attn.json`` so the
decode-attention perf trajectory is tracked PR over PR (the attention-side
companion of `bench_decode`'s GEMM-side numbers):

1. **Modeled HBM cache bytes per decoded token** (v5e roofline accounting,
   `tuning.decode_attn_cost`) at LLaMA-7B attention shapes, S ∈ {512, 2048},
   swept over valid prefix lengths L ∈ {S/8, S/2, S}. The jnp int8 path
   always streams the full S cache (the masked tail is read then written
   off with -1e30) and round-trips the (B, KVH, G, S) logits/probs through
   HBM; the Pallas kernel fetches ``ceil(L / block_s)`` blocks only
   (block-skip) and keeps the softmax state in VMEM. The gate: Pallas
   cache bytes strictly lower wherever L < S, total bytes strictly lower
   everywhere. ``block_s`` comes from `tuning.best_decode_attn_block` —
   the bench exercises the same pick serving uses.

2. **Smoke decode throughput** (CPU, tiny model): wall-clock tok/s of
   `Server.generate` under ``REPRO_DECODE_ATTN`` pallas vs int8 (on CPU the
   pallas mode falls back to the jnp int8 math, so this guards dispatch
   overhead), compared against the tok/s recorded in ``BENCH_decode.json``.
   CPU-indicative only; the modeled bytes carry the TPU claim.

Usage: PYTHONPATH=src python -m benchmarks.bench_decode_attn [--no-smoke]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.kernels import tuning

# LLaMA-7B attention at decode: B=4, 32 heads (MHA), head_dim 128
BATCH = 4
N_HEADS = 32
N_KV_HEADS = 32
HEAD_DIM = 128
SEQ_LENS = (512, 2048)

# CPU wall-clock slack for the smoke non-regression check (containers are
# noisy; the modeled bytes are the real gate)
SMOKE_SLACK = 0.5


def jnp_int8_bytes(s: int, valid_len: int) -> dict:
    """Modeled HBM traffic of the XLA-lowered int8 path for one step.

    Reads the full S cache regardless of ``valid_len`` and materializes the
    (B, KVH, G, S) intermediates: f32 logits and probs (each written then
    read back by the next op) plus the re-quantized int8 probs round-trip.
    """
    del valid_len  # read-then-mask: the tail is streamed anyway
    group = N_HEADS // N_KV_HEADS
    pos_bytes = 2 * HEAD_DIM + 2 * 4  # int8 k+v, f32 k/v scales
    cache = BATCH * N_KV_HEADS * s * pos_bytes
    rows = BATCH * N_KV_HEADS * group  # = B*H score rows
    inter = rows * s * ((4 + 4) * 2 + 1 * 2)  # logits, probs f32 + p_i8 r/w
    qo = BATCH * N_HEADS * HEAD_DIM * (4 + 4)
    return {"cache": float(cache), "total": float(cache + inter + qo)}


def pallas_bytes(s: int, valid_len: int) -> dict:
    """Modeled HBM traffic of the flash-decoding kernel for one step:
    one pass over the valid blocks of the cache, nothing S-sized written."""
    group = N_HEADS // N_KV_HEADS
    cand = tuning.best_decode_attn_block(BATCH, N_KV_HEADS, group, s,
                                         HEAD_DIM)
    r = tuning.decode_attn_cost(BATCH, N_KV_HEADS, group, s, HEAD_DIM,
                                block_s=cand.block_s, valid_len=valid_len)
    qo = BATCH * N_HEADS * HEAD_DIM * (4 + 4)
    return {"cache": float(r["cache_bytes"]),
            "total": float(r["cache_bytes"] + qo),
            "block_s": cand.block_s}


def smoke_decode_tok_s(mode: str, gen: int = 8, batch: int = 2) -> float:
    """Tiny-model wall-clock decode tok/s under one REPRO_DECODE_ATTN mode."""
    from repro.launch.serve import Server

    prev = os.environ.get("REPRO_DECODE_ATTN")
    os.environ["REPRO_DECODE_ATTN"] = mode
    try:
        server = Server(arch="qwen3-4b", smoke=True, w_bits=4, max_len=64)
        prompts = [[1, 2, 3, 4]] * batch
        # warmup at the SAME gen length (n_steps is a static jit arg)
        server.generate(prompts, max_new_tokens=gen)
        _, stats = server.generate(prompts, max_new_tokens=gen)
        return stats["decode_tok_s"]
    finally:
        if prev is None:
            os.environ.pop("REPRO_DECODE_ATTN", None)
        else:
            os.environ["REPRO_DECODE_ATTN"] = prev


def run(print_fn=print, smoke: bool = True,
        out_path: str = "BENCH_decode_attn.json") -> dict:
    results: dict = {"shapes": {"batch": BATCH, "n_heads": N_HEADS,
                                "n_kv_heads": N_KV_HEADS,
                                "head_dim": HEAD_DIM},
                     "seq_lens": {}}
    ok = True
    for s in SEQ_LENS:
        rows = {}
        for valid in (s // 8, s // 2, s):
            j = jnp_int8_bytes(s, valid)
            p = pallas_bytes(s, valid)
            per_tok_j = j["total"] / BATCH
            per_tok_p = p["total"] / BATCH
            cache_ok = p["cache"] < j["cache"] if valid < s \
                else p["cache"] <= j["cache"]
            total_ok = p["total"] < j["total"]
            ok = ok and cache_ok and total_ok
            rows[f"L{valid}"] = {
                "valid_len": valid,
                "block_s": p["block_s"],
                "cache_bytes_jnp_int8": j["cache"],
                "cache_bytes_pallas": p["cache"],
                "bytes_per_token_jnp_int8": per_tok_j,
                "bytes_per_token_pallas": per_tok_p,
                "cache_saved_frac": 1.0 - p["cache"] / j["cache"],
                "total_saved_frac": 1.0 - per_tok_p / per_tok_j,
            }
            print_fn(
                f"decode_attn_bytes,S={s},L={valid},bs={p['block_s']},"
                f"jnp={per_tok_j:.3e},pallas={per_tok_p:.3e},"
                f"cache_saved={rows[f'L{valid}']['cache_saved_frac']*100:.1f}%,"
                f"{'PASS' if cache_ok and total_ok else 'FAIL'}")
        results["seq_lens"][str(s)] = rows

    results["pallas_strictly_fewer_bytes"] = ok
    print_fn(f"decode_attn_check,pallas_bytes_strictly_lower,"
             f"{'PASS' if ok else 'FAIL'}")

    if smoke:
        tp = smoke_decode_tok_s("pallas")
        ti = smoke_decode_tok_s("int8")
        results["smoke_tok_s_pallas"] = tp
        results["smoke_tok_s_int8"] = ti
        baseline = None
        if os.path.exists("BENCH_decode.json"):
            with open("BENCH_decode.json") as f:
                prev = json.load(f)
            vals = [c.get("smoke_tok_s_fused")
                    for c in prev.get("configs", {}).values()
                    if c.get("smoke_tok_s_fused")]
            baseline = min(vals) if vals else None
        not_regressed = (baseline is None
                         or tp >= SMOKE_SLACK * baseline)
        results["smoke_baseline_tok_s"] = baseline
        results["smoke_not_regressed"] = not_regressed
        print_fn(f"decode_attn_smoke,pallas_tok_s={tp:.1f},"
                 f"int8_tok_s={ti:.1f},baseline={baseline},"
                 f"{'PASS' if not_regressed else 'FAIL'}  (CPU-indicative)")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print_fn(f"decode_attn_bench,wrote={out_path}")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--no-smoke", action="store_true",
                   help="skip the tiny-model wall-clock section")
    p.add_argument("--out", default="BENCH_decode_attn.json")
    args = p.parse_args(argv)
    r = run(smoke=not args.no_smoke, out_path=args.out)
    return 0 if r["pallas_strictly_fewer_bytes"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())

"""Quickstart: build a model, ABQ-quantize it, serve a few tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch llama-7b]

Uses the reduced smoke config so it runs on CPU in seconds. Shows the
paper's full deployment path: fp model -> RTN W2*A8 bit-plane packing ->
prefill -> autoregressive decode, with the memory win printed.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model, quantized_bytes


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama-7b")
    p.add_argument("--w-bits", type=int, default=2)
    p.add_argument("--a-bits", type=int, default=8)
    p.add_argument("--tokens", type=int, default=16)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    ctx = ModelContext(cfg=cfg, remat=False)
    key = jax.random.PRNGKey(0)
    print(f"[1/4] init {cfg.name} ({cfg.family}; {cfg.n_layers}L "
          f"d={cfg.d_model})")
    params = lm.init_params(key, cfg)

    qcfg = QuantizeConfig(w_bits=args.w_bits, a_bits=args.a_bits,
                          bit_balance=True)
    print(f"[2/4] quantize to {qcfg.tag()} (bit-plane packed)")
    qparams = quantize_model(params, cfg, qcfg)
    fp_b, q_b = quantized_bytes(params), quantized_bytes(qparams)
    print(f"      weights: {fp_b/1e6:.2f} MB -> {q_b/1e6:.2f} MB "
          f"({fp_b/q_b:.1f}x compression)")

    b, s = 2, 32
    ts = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    prompt = jax.random.randint(key, ts, 0, cfg.vocab_size)
    img = (jax.random.normal(key, (b, cfg.n_image_tokens, cfg.d_model),
                             jnp.bfloat16) * 0.05
           if cfg.family == "vlm" else None)

    print(f"[3/4] prefill {s} tokens")
    logits, cache = lm.prefill(qparams, prompt, cfg, ctx,
                               max_len=s + args.tokens + 1, image_embeds=img)

    print(f"[4/4] decode {args.tokens} tokens (ABQ integer path)")
    decode = jax.jit(lambda qp, c, t: lm.decode_step(qp, c, t, cfg, ctx))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    for _ in range(args.tokens - 1):
        logits, cache = decode(qparams, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    seq = jnp.concatenate(outs, axis=1)
    print("      sampled token ids (greedy, seq 0):",
          [int(x) for x in (seq[0, :, 0] if seq.ndim == 3 else seq[0])])
    print("done.")


if __name__ == "__main__":
    main()

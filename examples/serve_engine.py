"""Continuous-batching engine demo: ragged requests, streamed tokens.

    PYTHONPATH=src python examples/serve_engine.py [--arch qwen3-4b] [--paged]

Drives `repro.serving.Engine` directly (the production serving path):
requests with different prompt lengths, generation budgets, stop tokens and
per-request sampling parameters are submitted while the engine runs; the
engine admits them into free cache slots between decode steps, retires rows
on EOS/max-tokens, and reuses the slots immediately. Compare
examples/serve_quantized.py — the static lockstep batcher over the same
quantized model.

``--paged`` switches the KV cache to block-granular paged allocation
(`repro.serving.BlockPool`): admission is then bounded by free 16-token
blocks rather than free max_len rows, and the final report prints the
pool accounting next to the slot stats.

``--chunk N`` feeds prompts longer than N through chunked prefill (one
N-token chunk per engine step, `kernels/chunk_attn.py`'s prefix-clamped
attention) so a long prompt never stalls running decodes — composable
with ``--paged`` since the paged `attend_chunk` landed.

``--metrics`` prints the operator snapshot after the drain — the same
`Engine.metrics.snapshot()` dict a monitoring scraper would read:
request latency percentiles (TTFT/TPOT/e2e/queue-wait), lifecycle and
backpressure counters, occupancy/free-block gauges, terminal-reason
breakdown, and where each step's wall-clock went (host vs prefill vs
device).

``--deadline-s N`` attaches a wall-clock deadline to every request
(expired requests retire as ``timed_out`` between steps, freeing their
capacity); ``--cancel-after N`` cancels the last-submitted request after
N engine steps (`Engine.cancel` is safe at any lifecycle stage). The
final report includes the terminal-reason summary either way.
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.launch.serve import Server
from repro.serving import Request, SamplingParams


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--w-bits", type=int, default=2)
    p.add_argument("--paged", action="store_true",
                   help="paged KV: 16-token blocks, pool sized to the "
                        "slot-row byte budget")
    p.add_argument("--chunk", type=int, default=None,
                   help="chunked prefill: feed long prompts N tokens per "
                        "engine step (composes with --paged)")
    p.add_argument("--metrics", action="store_true",
                   help="print the Engine.metrics.snapshot() summary "
                        "table after the drain")
    p.add_argument("--deadline-s", type=float, default=None,
                   help="attach a wall-clock deadline to every request: "
                        "a request still unfinished after N seconds is "
                        "retired as timed_out, freeing its slot/blocks")
    p.add_argument("--cancel-after", type=int, default=None,
                   help="cancel the last-submitted request after N engine "
                        "steps (demonstrates Engine.cancel at whatever "
                        "lifecycle stage it is in)")
    args = p.parse_args()

    server = Server(arch=args.arch, smoke=True, w_bits=args.w_bits,
                    max_len=128)
    engine_kw = {"kv_block_size": 16} if args.paged else {}
    if args.chunk is not None:
        engine_kw["prefill_chunk"] = args.chunk
    engine = server.engine(n_slots=args.slots, prefill_bucket=8, **engine_kw)
    rng = np.random.default_rng(0)

    states = []
    for i in range(args.requests):
        prompt = rng.integers(0, server.cfg.vocab_size,
                              size=int(rng.integers(4, 20))).tolist()
        sampling = SamplingParams(greedy=(i % 2 == 0), temperature=0.8,
                                  top_k=32, top_p=0.9, seed=i)
        states.append(engine.submit(Request(
            prompt=tuple(prompt),
            max_new_tokens=int(rng.integers(4, 24)),
            deadline_s=args.deadline_s,
            sampling=sampling)))
    print(f"submitted {len(states)} requests into {args.slots} slots "
          f"(queue depth {len(engine.scheduler)})")

    steps = 0
    while engine.has_work():
        engine.step()
        steps += 1
        if args.cancel_after is not None and steps == args.cancel_after:
            victim = states[-1]
            if engine.cancel(victim.request_id):
                print(f"      cancelled req{victim.request_id} "
                      f"(was {victim.status})")
        running = [s.request_id for s in states if s.status == "running"]
        print(f"step {engine.stats['steps']:3d}: running={running} "
              f"queued={len(engine.scheduler)} "
              f"finished={engine.stats['finished']}")

    for st in states:
        kind = "greedy" if st.request.sampling.greedy else "sampled"
        print(f"req{st.request_id} [{kind:7s}] +{len(st.tokens)} tokens "
              f"({st.status}/{st.finish_reason}): {st.output()[:8]}...")
    occ = engine.stats["occupancy_sum"] / max(engine.stats["device_steps"], 1)
    print(f"device steps: {engine.stats['device_steps']} | "
          f"mean occupancy: {occ:.2f} | "
          f"host transfers: {engine.stats['transfers']}")
    s = engine.stats
    print(f"terminal: finished={s['finished']} timed_out={s['timed_out']} "
          f"cancelled={s['cancelled']} failed={s['failed']}")
    if engine.pool is not None:
        print(f"paged pool: {engine.pool.stats()}")
    if args.metrics:
        print_metrics(engine.metrics.snapshot())


def print_metrics(snap):
    """Operator summary table off the stable snapshot dict."""
    ms = 1e3

    print(f"\n-- engine metrics (schema v{snap['schema_version']}, "
          f"{snap['elapsed_s']:.2f}s elapsed) --")
    print("latency                p50        p90        p99      count")
    for name in ("ttft", "tpot", "e2e", "queue_wait"):
        h = snap["latency_s"][name]
        print(f"  {name:<12s}"
              + "".join(f"{h[p] * ms:9.2f}ms" for p in ("p50", "p90", "p99"))
              + f"{h['count']:8d}")
    c = snap["counters"]
    print(f"requests: {c['submitted']} submitted, {c['admitted']} admitted, "
          f"{c['finished']} finished "
          f"(eos={c['finished_eos']}, length={c['finished_length']})")
    t = snap["terminal"]
    print(f"terminal: finished={t['finished']} timed_out={t['timed_out']} "
          f"cancelled={t['cancelled']} failed={t['failed']} "
          f"in_flight={t['in_flight']}")
    print(f"tokens:   {c['tokens_out']} out | "
          f"goodput {snap['throughput']['goodput_tok_s']:.1f} tok/s "
          f"(raw {snap['throughput']['tok_s']:.1f})")
    print(f"blocked:  slots={c['blocked_on_slots']} "
          f"blocks={c['blocked_on_blocks']} budget={c['blocked_on_budget']} "
          f"| horizon waste {c['horizon_waste_steps']} slot-steps")
    g = snap["gauges"]
    blocks = ("" if g["free_blocks"]["samples"] == 0
              else f" | free blocks min={g['free_blocks']['min']:.0f}")
    print(f"gauges:   occupancy mean={g['slot_occupancy']['mean']:.2f} "
          f"max={g['slot_occupancy']['max']:.2f} | "
          f"queue mean={g['queue_depth']['mean']:.1f} "
          f"max={g['queue_depth']['max']:.0f}{blocks}")
    ph = snap["phase_s"]
    tot = max(ph["host"]["total"] + ph["prefill"]["total"]
              + ph["device"]["total"], 1e-9)
    print(f"phases:   host {ph['host']['total'] / tot * 100:.0f}% | "
          f"prefill {ph['prefill']['total'] / tot * 100:.0f}% | "
          f"device {ph['device']['total'] / tot * 100:.0f}% "
          f"of {tot:.2f}s stepped")


if __name__ == "__main__":
    main()

"""Batched quantized serving loop (continuous prefill + decode).

    PYTHONPATH=src python examples/serve_quantized.py [--arch qwen3-4b]

Drives `repro.launch.serve.Server`: requests arrive with different prompt
lengths, get batched, prefilled, then decoded together with the ABQ W2*A8
integer path; per-phase throughput is reported. CPU-sized smoke config.
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.launch.serve import Server


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--gen-tokens", type=int, default=24)
    p.add_argument("--w-bits", type=int, default=2)
    args = p.parse_args()

    server = Server(arch=args.arch, smoke=True, w_bits=args.w_bits,
                    max_len=128)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, server.cfg.vocab_size,
                            size=rng.integers(8, 32)).tolist()
               for _ in range(args.requests)]
    print(f"serving {len(prompts)} requests "
          f"(prompt lens {[len(q) for q in prompts]})")
    outs, stats = server.generate(prompts, max_new_tokens=args.gen_tokens)
    for i, o in enumerate(outs):
        print(f"  req{i}: +{len(o)} tokens: {o[:10]}...")
    print(f"prefill: {stats['prefill_tok_s']:.0f} tok/s | "
          f"decode: {stats['decode_tok_s']:.1f} tok/s | "
          f"weights {stats['weight_mb']:.1f} MB ({stats['qtag']})")


if __name__ == "__main__":
    main()

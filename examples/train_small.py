"""End-to-end training driver example: train an LM for a few hundred steps
with checkpoints, straggler watch, and crash-resume.

    PYTHONPATH=src python examples/train_small.py              # ~10M, minutes
    PYTHONPATH=src python examples/train_small.py --preset 100m --steps 300

(The 100m preset is the assignment's "~100M model for a few hundred steps";
on this CPU-only container it takes hours, so the default preset is a ~10M
model that shows the same loss curve shape in minutes. Both run the exact
production code path: repro.launch.train.run.)
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.supervisor import supervise


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="10m", choices=["10m", "100m"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--ckpt", default="/tmp/repro_example_train")
    p.add_argument("--inject-failure", action="store_true",
                   help="crash mid-run to demo supervisor restart")
    args = p.parse_args()

    if args.preset == "100m":
        # ~100M params: 12L d=512 ff=2048 vocab=8192 — register on the fly
        import repro.configs as C

        cfg = C.ArchConfig(name="example-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=12,
                           d_ff=2048, vocab_size=8192)
        import repro.configs.llama_7b as llama_mod

        llama_mod.SMOKE = cfg  # reuse the llama entry with our config
        argv = ["--arch", "llama-7b", "--smoke", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "256"]
    else:
        argv = ["--arch", "llama-7b", "--smoke", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "128"]
    argv += ["--checkpoint-dir", args.ckpt, "--checkpoint-every", "50",
             "--lr", "3e-3"]
    if args.inject_failure:
        argv += ["--fail-at-step", str(args.steps // 2)]
    result = supervise(argv, max_restarts=2)
    print(f"final loss: {result['final_loss']:.4f} "
          f"(restarts: {result['restarts']}, "
          f"stragglers flagged: {result['straggler_steps']})")


if __name__ == "__main__":
    main()

"""End-to-end ABQ-LLM calibration example (the paper's §3 pipeline).

    PYTHONPATH=src python examples/calibrate_abq.py [--w-bits 2] [--a-bits 8]

1. trains a small LM on the synthetic distribution (so quantization has a
   real accuracy signal),
2. runs the paper's block-wise calibration (SmoothQuant-init balance
   vectors, learnable clipping, compensation vectors on edge blocks,
   DLC + AKL losses, AdamW),
3. packs the calibrated weights into bit-planes,
4. reports perplexity: fp vs RTN vs ABQ-calibrated — reproducing the
   paper's central accuracy claim (Table 2) directionally.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax.numpy as jnp

from benchmarks.common import trained_bench_model
from repro.core.calibration import CalibConfig, calibrate_model, stack_qstates
from repro.data.synthetic import calibration_segments
from repro.eval.ppl import perplexity
from repro.models.quantized import QuantizeConfig, quantize_model


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--w-bits", type=int, default=2)
    p.add_argument("--a-bits", type=int, default=8)
    p.add_argument("--bit-balance", action="store_true", default=True)
    p.add_argument("--epochs", type=int, default=6)
    p.add_argument("--segments", type=int, default=2)
    args = p.parse_args()

    print("[1/4] training the benchmark LM (cached across runs)...")
    params, cfg, ctx = trained_bench_model()
    ppl_fp = perplexity(params, cfg, ctx)
    print(f"      fp perplexity: {ppl_fp:.3f}")

    tag = f"W{args.w_bits}{'*' if args.bit_balance else ''}A{args.a_bits}"
    qcfg = QuantizeConfig(w_bits=args.w_bits, a_bits=args.a_bits,
                          bit_balance=args.bit_balance)
    print(f"[2/4] RTN baseline at {tag}...")
    ppl_rtn = perplexity(quantize_model(params, cfg, qcfg), cfg, ctx)
    print(f"      RTN perplexity: {ppl_rtn:.3f}")

    print(f"[3/4] ABQ block-wise calibration ({args.epochs} epochs × "
          f"{args.segments} segments; DLC + AKL)...")
    t0 = time.time()
    calib_tokens = jnp.asarray(calibration_segments(
        cfg.vocab_size, n_segments=args.segments, seq_len=64, batch=2))
    ccfg = CalibConfig(w_bits=args.w_bits, a_bits=args.a_bits,
                       bit_balance=args.bit_balance, epochs=args.epochs)
    states = calibrate_model(params, calib_tokens, cfg, ccfg)
    calib = {"blocks": stack_qstates(states)}
    print(f"      calibrated {cfg.n_layers} blocks in {time.time()-t0:.0f}s")

    print("[4/4] pack + evaluate...")
    qp = quantize_model(params, cfg, qcfg, calib=calib)
    ppl_abq = perplexity(qp, cfg, ctx)
    print(f"\n  {'config':<12} {'ppl':>8}")
    print(f"  {'fp':<12} {ppl_fp:>8.3f}")
    print(f"  {tag + ' RTN':<12} {ppl_rtn:>8.3f}")
    print(f"  {tag + ' ABQ':<12} {ppl_abq:>8.3f}")
    gain = (ppl_rtn - ppl_abq) / max(ppl_rtn - ppl_fp, 1e-9)
    print(f"\n  calibration recovers {100*gain:.0f}% of the RTN degradation")


if __name__ == "__main__":
    main()

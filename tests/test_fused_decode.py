"""Fused decode fast-path tests: ReQuant+GEMM fusion parity, autotune
cache behavior, and the scan-based generation loop.

The fused kernel runs in interpret mode (kernel body executes in Python on
CPU); parity is asserted three ways:
  * the int8 activation container is **bitwise identical** to the unfused
    `act_quant_ref` path (the fusion must not change the quantization);
  * the fused output matches the `ref.py` oracle pipeline to fp32 tolerance;
  * the ops-level dispatch (fused vs unfused vs XLA) agrees.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, pack_weight
from repro.kernels import ops as O
from repro.kernels import ref as R
from repro.kernels import tuning
from repro.kernels.abq_fused import abq_linear_fused_pallas, fits_vmem


def _mk(rng, m, k, n, w_bits):
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    pw = pack_weight(w, QuantSpec(bits=w_bits, bit_balance=(w_bits <= 3)))
    return x, pw


# ---------------------------------------------------------------------------
# fused kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 3, 17])
@pytest.mark.parametrize("k,n", [(72, 128), (200, 128)])  # K % 32 != 0
@pytest.mark.parametrize("w_bits", [2, 3, 4, 8])
def test_fused_requant_gemm_parity(rng, m, k, n, w_bits):
    x, pw = _mk(rng, m, k, n, w_bits)
    kp = pw.planes.shape[1] * 32
    x_pad = jnp.pad(x, ((0, 0), (0, kp - k)))

    out, q, s = abq_linear_fused_pallas(
        x_pad, pw.planes, pw.scale, pw.zero_point,
        qmax=127.0, block_m=8, block_n=128, out_dtype=jnp.float32,
        debug_return_quant=True, interpret=True)

    # (a) int8 container bitwise identical to the unfused quantizer
    q_ref, s_ref = R.act_quant_ref(x_pad, qmax=127.0)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-6, atol=0)

    # (b) fused output matches the oracle pipeline
    y_ref = R.abq_matmul_ref(q_ref, s_ref, pw.planes, pw.scale,
                             pw.zero_point, kp, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("w_bits", [2, 4])
def test_abq_linear_dispatch_fused_equals_unfused(rng, w_bits):
    """ops.abq_linear: fused pallas == unfused pallas == fused XLA."""
    x, pw = _mk(rng, 5, 96, 128, w_bits)
    kw = dict(out_dtype=jnp.float32)
    y_fp = O.abq_linear(x, pw, backend="pallas", interpret=True,
                        fused=True, **kw)
    y_up = O.abq_linear(x, pw, backend="pallas", interpret=True,
                        fused=False, **kw)
    y_fx = O.abq_linear(x, pw, backend="xla", fused=True, **kw)
    y_ux = O.abq_linear(x, pw, backend="xla", fused=False, **kw)
    np.testing.assert_allclose(np.asarray(y_fp), np.asarray(y_up),
                               rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_fx), np.asarray(y_ux),
                               rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_fp), np.asarray(y_fx),
                               rtol=1e-6, atol=1e-5)


def test_fused_toggle_env_validation(rng, monkeypatch):
    x, pw = _mk(rng, 2, 64, 128, 2)
    monkeypatch.setenv("REPRO_ABQ_FUSED", "0")
    y0 = O.abq_linear(x, pw, backend="xla", out_dtype=jnp.float32)
    monkeypatch.setenv("REPRO_ABQ_FUSED", "1")
    y1 = O.abq_linear(x, pw, backend="xla", out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-6, atol=1e-5)
    monkeypatch.setenv("REPRO_ABQ_FUSED", "maybe")
    with pytest.raises(ValueError, match="REPRO_ABQ_FUSED"):
        O.abq_linear(x, pw, backend="xla", out_dtype=jnp.float32)


def test_fused_leading_dims_and_act_inv_s(rng):
    """apply_linear threads 3-D activations through the fused path."""
    from repro.models.layers import QuantLinear, apply_linear

    x, pw = _mk(rng, 6, 64, 128, 2)
    x3 = x.reshape(2, 3, 64)
    ql = QuantLinear(pw=pw, act_inv_s=None, act_bits=8)
    y = apply_linear(x3, ql, backend="pallas", interpret=True)
    y2 = O.abq_linear(x, pw, backend="xla", out_dtype=x.dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32).reshape(6, 128),
                               np.asarray(y2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fits_vmem_gates_full_k_tiles():
    assert fits_vmem(8, 4096, 128, 2, tuning.VMEM_BYTES // 4)
    assert not fits_vmem(256, 65536, 4096, 8, 1 << 20)


# ---------------------------------------------------------------------------
# autotune dispatch cache
# ---------------------------------------------------------------------------


def test_best_blocks_decode_shapes_pick_small_bm():
    """Decode GEMV/GEMM shapes (M = batch) must select BM <= 32 — the
    whole point of the decode-shaped path: no padded-row MXU waste."""
    for m in (1, 4, 8, 32):
        for k, n in [(4096, 4096), (4096, 11008), (11008, 4096)]:
            cand = tuning.best_blocks(m, k, n, 2)
            assert cand.block_m <= 32, (m, k, n, cand)
    # prefill keeps MXU-saturating tiles
    assert tuning.best_blocks(4096, 4096, 4096, 2).block_m >= 64


def test_best_blocks_is_cached_and_kernel_legal():
    a = tuning.best_blocks(7, 96, 128, 2)
    b = tuning.best_blocks(7, 96, 128, 2)
    assert a is b  # lru_cache hit, not a re-search
    assert 96 % a.block_k == 0 and a.block_k % 32 == 0
    assert 128 % a.block_n == 0


def test_abq_matmul_autotuned_blocks_match_pinned(rng):
    """Default (autotuned) block selection changes tiling, not numerics."""
    from repro.core import act_scales, quantize_act

    x, pw = _mk(rng, 3, 96, 128, 2)
    aspec = QuantSpec(bits=8, symmetric=True, granularity="per_token")
    xs = act_scales(x, aspec)
    xq = quantize_act(x, xs, aspec)
    y_auto = O.abq_matmul(xq, xs, pw, backend="pallas", interpret=True,
                          out_dtype=jnp.float32)
    y_pin = O.abq_matmul(xq, xs, pw, backend="pallas", interpret=True,
                         block_m=32, block_n=128, block_k=96,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_pin),
                               rtol=1e-6, atol=1e-5)


# ---------------------------------------------------------------------------
# act_qmax / decode_attention mode hygiene
# ---------------------------------------------------------------------------


def test_act_qmax_table():
    assert O.act_qmax(8) == 127.0
    assert O.act_qmax(4) == 7.0
    assert O.act_qmax(3) == 3.0
    assert O.act_qmax(2) == 1.0
    assert O.act_qmax(1) == 1.0
    for bad in (0, 9, -1):
        with pytest.raises(ValueError):
            O.act_qmax(bad)


def test_decode_attention_rejects_unknown_mode(rng, monkeypatch):
    q = jnp.asarray(rng.normal(size=(1, 1, 4, 8)).astype(np.float32))
    kc = jnp.zeros((1, 2, 4, 8), jnp.int8)
    vc = jnp.zeros((1, 2, 4, 8), jnp.int8)
    ks = jnp.ones((1, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="decode_attention mode"):
        O.decode_attention(q, kc, vc, ks, ks, fused_dequant="turbo")
    monkeypatch.setenv("REPRO_DECODE_ATTN", "warp9")
    with pytest.raises(ValueError, match="REPRO_DECODE_ATTN"):
        O.decode_attention(q, kc, vc, ks, ks)


# ---------------------------------------------------------------------------
# scan-based generation
# ---------------------------------------------------------------------------


def test_generate_tokens_matches_stepwise_loop(key):
    """The lax.scan decode loop must emit exactly the tokens the per-step
    Python loop produced (same cache evolution, same argmax stream)."""
    from conftest import tiny
    from repro.models import lm
    from repro.models.blocks import ModelContext
    from repro.models.quantized import QuantizeConfig, quantize_model

    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    n_steps = 5

    logits, cache0 = lm.prefill(qp, tokens, cfg, ctx, max_len=32)
    first = jnp.argmax(logits, -1).astype(jnp.int32)

    # reference: the old per-step loop
    ref_toks = []
    tok, cache = first, cache0
    for _ in range(n_steps):
        ref_toks.append(np.asarray(tok))
        lo, cache = lm.decode_step(qp, cache, tok, cfg, ctx)
        tok = jnp.argmax(lo, -1).astype(jnp.int32)

    logits2, cache1 = lm.prefill(qp, tokens, cfg, ctx, max_len=32)
    first2 = jnp.argmax(logits2, -1).astype(jnp.int32)
    gen, _ = lm.generate_tokens(qp, cache1, first2, n_steps, cfg, ctx)
    np.testing.assert_array_equal(np.asarray(gen), np.stack(ref_toks))


def test_server_generate_single_host_transfer(monkeypatch):
    """Server.generate moves output tokens device→host exactly once."""
    import repro.launch.serve as serve_mod

    server = serve_mod.Server(arch="qwen3-4b", smoke=True, w_bits=2,
                              max_len=64)
    transfers = {"n": 0}
    orig = np.asarray

    def counting_asarray(a, *args, **kw):
        if isinstance(a, jax.Array):
            transfers["n"] += 1
        return orig(a, *args, **kw)

    monkeypatch.setattr(serve_mod.np, "asarray", counting_asarray)
    outs, stats = server.generate([[1, 2, 3], [4, 5]], max_new_tokens=4)
    assert transfers["n"] == 1
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(isinstance(t, int) for o in outs for t in o)

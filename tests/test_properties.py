"""Hypothesis property tests on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    QuantSpec,
    act_scales,
    dequantize_weight,
    pack_bitplanes,
    quantize_act,
    quantize_weight,
    unpack_levels,
    weight_scales,
)
from repro.kernels import ref as R

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(
    bits=st.integers(1, 8),
    k=st.integers(1, 80),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitplane_roundtrip(bits, k, n, seed):
    """pack -> unpack is the identity for any level matrix."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 2**bits, size=(k, n)), jnp.int32)
    planes = pack_bitplanes(q, bits)
    lv = unpack_levels(planes, k)
    assert np.array_equal(np.asarray(q), np.asarray(lv))


@given(
    bits=st.integers(2, 8),
    bb=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    scale_pow=st.integers(-3, 3),
)
def test_weight_quant_error_bounded(bits, bb, seed, scale_pow):
    """|dequant(quant(w)) - w| <= scale/2 inside the (unclipped) range."""
    if bb and bits >= 8:
        return
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(32, 4)) * 10.0**scale_pow, jnp.float32)
    spec = QuantSpec(bits=bits, bit_balance=bb)
    sc, zp = weight_scales(w, spec)
    q = quantize_weight(w, sc, zp, spec)
    wd = dequantize_weight(q, sc, zp, spec)
    assert np.all(np.abs(np.asarray(wd - w)) <= np.asarray(sc) / 2 * 1.001 + 1e-7)


@given(
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_act_quant_monotone(bits, seed):
    """Quantization preserves per-token ordering up to one level."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.normal(size=(1, 64))), jnp.float32)
    spec = QuantSpec(bits=bits, symmetric=True, granularity="per_token")
    q = quantize_act(x, act_scales(x, spec), spec)
    dq = np.diff(np.asarray(q[0], np.int32))
    assert np.all(dq >= 0)


@given(
    m=st.integers(1, 9),
    k=st.integers(1, 6),
    n=st.integers(1, 4),
    w_bits=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_integer_gemm_identity_exact(m, k, n, w_bits, seed):
    """The bit-plane GEMM identity is EXACT integer math: for any int8
    activations and any packed weight levels,
      sum_s 2^s (X @ W^s) - zp*rowsum == X @ (W_q - zp)."""
    rng = np.random.default_rng(seed)
    kk = k * 32  # packing word multiple
    xq = jnp.asarray(rng.integers(-127, 128, size=(m, kk)), jnp.int8)
    wq = jnp.asarray(rng.integers(0, 2**w_bits, size=(kk, n)), jnp.int32)
    zp = jnp.asarray(rng.uniform(0, 2**w_bits - 1, size=(1, n)), jnp.float32)
    planes = pack_bitplanes(wq, w_bits)
    ones = jnp.ones((m, 1), jnp.float32)
    y = R.abq_matmul_ref(xq, ones, planes, jnp.ones((1, n), jnp.float32),
                         zp, kk, out_dtype=jnp.float32)
    expected = xq.astype(jnp.int32) @ wq.astype(jnp.int32)
    expected = expected.astype(jnp.float32) - zp * jnp.sum(
        xq.astype(jnp.float32), axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                               rtol=1e-6, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), length=st.integers(2, 40))
def test_data_pipeline_deterministic(seed, length):
    """(seed, index) fully determines a sample — fault-tolerant resume
    reproduces identical batches."""
    from repro.data import DataConfig, SyntheticLM

    cfg = DataConfig(vocab_size=97, seq_len=length, seed=seed)
    a = SyntheticLM(cfg).sample(7)
    b = SyntheticLM(cfg).sample(7)
    assert np.array_equal(a, b)
    c = SyntheticLM(cfg).sample(8)
    assert not np.array_equal(a, c) or length < 3


@given(
    seed=st.integers(0, 2**31 - 1),
    n_hosts=st.sampled_from([1, 2, 4]),
)
def test_data_host_sharding_partitions(seed, n_hosts):
    """Per-host batches tile the global batch exactly."""
    from repro.data import DataConfig, SyntheticLM

    ds = SyntheticLM(DataConfig(vocab_size=31, seq_len=8, seed=seed))
    full = ds.batch(3, 8, host_id=0, n_hosts=1)["tokens"]
    parts = [ds.batch(3, 8, host_id=h, n_hosts=n_hosts)["tokens"]
             for h in range(n_hosts)]
    assert np.array_equal(np.concatenate(parts), full)

"""Serving telemetry tests.

The load-bearing claim is **zero interference**: the metrics facade is a
host-side observer, so engine outputs are bitwise identical with metrics
on, off, or logging to a JSONL sink. Around that: the dependency-free
primitives (exact percentile helpers vs numpy, log-bucket histogram
error bounds), deterministic request-lifecycle accounting under a
`FakeClock` (event ordering through chunked+paged admission, queue-wait/
TTFT/TPOT derived from the monotonic stamps), a counter-conservation
invariant checked after every step, horizon-waste attribution, and the
stability of the `snapshot()` schema that operators script against.
"""

import json

import numpy as np
import pytest

import jax

from conftest import tiny
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model
from repro.serving import (Engine, EngineMetrics, FakeClock, Request,
                           RequestState, SamplingParams, Scheduler)
from repro.serving.metrics import (SCHEMA_VERSION, Gauge, Histogram,
                                   check_snapshot, pcts_ms, percentiles)
from repro.serving.request import FINISHED, PREFILLING, QUEUED, RUNNING


@pytest.fixture(scope="module")
def served():
    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    return cfg, ctx, qp


def _engine(served, **kw):
    cfg, ctx, qp = served
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_bucket", 4)
    return Engine(qp, cfg, ctx, **kw)


def _prompts(cfg, rng, n, lo=3, hi=12):
    return [rng.integers(0, cfg.vocab_size, size=int(s)).tolist()
            for s in rng.integers(lo, hi, size=n)]


# ---------------------------------------------------------------------------
# primitives: exact percentiles, gauge, log-bucket histogram
# ---------------------------------------------------------------------------


def test_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    vals = rng.exponential(scale=3.0, size=37).tolist()
    ps = (0, 10, 25, 50, 90, 99, 100)
    ours = percentiles(vals, ps)
    theirs = [float(np.percentile(np.asarray(vals), p)) for p in ps]
    assert np.allclose(ours, theirs, rtol=1e-12)
    assert percentiles([], (50, 99)) == [0.0, 0.0]
    assert percentiles([4.2], (0, 50, 100)) == [4.2, 4.2, 4.2]


def test_pcts_ms_schema():
    r = pcts_ms([0.001, 0.002, 0.003])
    assert set(r) == {"p50_ms", "p99_ms"}
    assert r["p50_ms"] == pytest.approx(2.0)
    assert pcts_ms([]) == {"p50_ms": 0.0, "p99_ms": 0.0}


def test_gauge_summary():
    g = Gauge()
    assert g.summary() == {"last": None, "min": None, "max": None,
                           "mean": None, "samples": 0}
    for v in (1.0, 3.0, 2.0):
        g.set(v)
    s = g.summary()
    assert s == {"last": 2.0, "min": 1.0, "max": 3.0, "mean": 2.0,
                 "samples": 3}


def test_histogram_bucket_bounds_contain_values():
    h = Histogram(lo=1e-6, hi=1e4, buckets_per_decade=8)
    rng = np.random.default_rng(1)
    for v in 10.0 ** rng.uniform(-5.5, 3.5, size=200):
        i = h._index(v)
        lo, hi = h.bucket_bounds(i)
        assert lo <= v < hi * (1 + 1e-12)
    # out-of-range values clamp to the end buckets instead of dropping
    assert h._index(1e-9) == 0
    assert h._index(1e9) == len(h.counts) - 1


def test_histogram_percentile_within_one_bucket():
    """Estimates must land within one bucket growth factor (~33% at
    8/decade) of the exact order statistic, and p0/p100 are exact."""
    h = Histogram(buckets_per_decade=8)
    rng = np.random.default_rng(2)
    vals = (10.0 ** rng.uniform(-4, 1, size=500)).tolist()
    for v in vals:
        h.record(v)
    g = h._g * 1.01  # one bucket of slack, plus float fuzz
    for p in (1, 10, 50, 90, 99):
        exact = float(np.percentile(np.asarray(vals), p))
        est = h.percentile(p)
        assert exact / g <= est <= exact * g, (p, exact, est)
    assert h.percentile(0) == pytest.approx(min(vals))
    assert h.percentile(100) == pytest.approx(max(vals))
    s = h.summary()
    assert s["count"] == 500
    assert s["mean"] == pytest.approx(float(np.mean(vals)))


def test_histogram_degenerate_cases():
    h = Histogram()
    assert h.percentile(50) == 0.0  # empty
    h.record(0.0421)
    for p in (0, 50, 100):
        assert h.percentile(p) == pytest.approx(0.0421)  # clamped exact
    assert h.summary()["min"] == h.summary()["max"] == 0.0421


# ---------------------------------------------------------------------------
# lifecycle accounting: chunked+paged admission under a fake clock
# ---------------------------------------------------------------------------


def test_lifecycle_events_and_latency_chunked_paged(served, tmp_path):
    """6 requests (prompts long enough to chunk) through a 2-slot
    chunked+paged engine on a FakeClock: the JSONL sink must show each
    request's events in lifecycle order with non-decreasing monotonic
    stamps, and the snapshot's queue-wait/TTFT/TPOT histograms must agree
    exactly with the per-request monotonic stamps."""
    cfg, _, _ = served
    log = tmp_path / "events.jsonl"
    clk = FakeClock()
    mx = EngineMetrics(clock=clk, log_path=str(log))
    eng = _engine(served, prefill_chunk=4, kv_block_size=8,
                  clock=clk, metrics=mx)
    rng = np.random.default_rng(3)
    prompts = _prompts(cfg, rng, 6, lo=6, hi=12)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=4))
              for p in prompts]
    while eng.has_work():
        eng.step()
        clk.advance(0.5)

    events = [json.loads(line) for line in log.read_text().splitlines()]
    assert all({"t", "t_wall", "event"} <= set(e) for e in events)
    stamps = [e["t"] for e in events]
    assert stamps == sorted(stamps)  # monotonic clock, append order

    order = {"submit": 0, "admit": 1, "prefill_chunk": 2, "first_token": 3,
             "retire": 4}
    for st in states:
        seq = [e["event"] for e in events
               if e.get("request_id") == st.request_id]
        assert seq[0] == "submit" and seq[-1] == "retire"
        assert [order[n] for n in seq] == sorted(order[n] for n in seq)
        n_chunks = seq.count("prefill_chunk")
        assert n_chunks == -(-len(st.request.prompt) // 4)  # every chunk
        assert seq.count("admit") == seq.count("first_token") == 1

    snap = eng.metrics.snapshot()
    assert check_snapshot(snap) == []
    waits = [s.admit_t - s.submit_t for s in states]
    ttfts = [s.first_token_t - s.submit_t for s in states]
    tpots = [(s.finish_t - s.first_token_t) / (len(s.tokens) - 1)
             for s in states]
    for name, vals in (("queue_wait", waits), ("ttft", ttfts),
                       ("tpot", tpots)):
        h = snap["latency_s"][name]
        assert h["count"] == len(states)
        assert h["min"] == pytest.approx(min(vals))
        assert h["max"] == pytest.approx(max(vals))
    c = snap["counters"]
    assert c["prefill_chunks"] == sum(-(-len(p) // 4) for p in prompts)
    assert c["blocked_on_slots"] > 0  # 6 requests queued behind 2 slots
    assert c["finished"] == c["finished_length"] == 6
    assert c["tokens_out"] == c["tokens_finished"] == 24
    # monotonic submit stamps never go backwards even if wall clock would
    assert all(s.submit_t <= s.admit_t <= s.first_token_t <= s.finish_t
               for s in states)
    mx.close()


def test_counter_conservation_every_step(served):
    """At every step: submitted == queued + in-flight + finished, and the
    admitted counter covers exactly the requests that left the queue."""
    cfg, _, _ = served
    clk = FakeClock()
    eng = _engine(served, clock=clk)
    rng = np.random.default_rng(4)
    prompts = _prompts(cfg, rng, 6)
    gens = [int(g) for g in rng.integers(2, 7, size=6)]
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=g))
              for p, g in zip(prompts, gens)]
    c = eng.metrics.counters
    assert c["submitted"] == 6 and c["admitted"] == 0
    while eng.has_work():
        eng.step()
        clk.advance(0.25)
        by = {s: 0 for s in (QUEUED, PREFILLING, RUNNING, FINISHED)}
        for st in states:
            by[st.status] += 1
        assert c["submitted"] == sum(by.values()) == 6
        assert c["finished"] == by[FINISHED]
        assert c["admitted"] == 6 - by[QUEUED]
        assert c["tokens_out"] == sum(len(s.tokens) for s in states)
        assert len(eng.scheduler) == by[QUEUED]
    assert c["finished"] == 6
    assert c["tokens_finished"] == c["tokens_out"] == sum(gens)
    snap = eng.metrics.snapshot()
    assert snap["gauges"]["queue_depth"]["last"] == 0.0
    assert snap["gauges"]["slot_occupancy"]["max"] <= 1.0
    # unpaged engine: the free-blocks gauge is never sampled
    assert snap["gauges"]["free_blocks"]["samples"] == 0


def test_horizon_waste_accounting(served):
    """A request finishing mid-horizon strands H-1-h slot-steps: with
    H=4, a 5-token budget retires at h=0 of its second block (waste 3), a
    4-token budget exactly fills one block (waste 0)."""
    for max_new, expect in ((5, 3), (4, 0)):
        eng = _engine(served, step_horizon=4)
        eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=max_new))
        eng.run()
        assert eng.metrics.counters["horizon_waste_steps"] == expect


# ---------------------------------------------------------------------------
# zero interference: metrics cannot change a token
# ---------------------------------------------------------------------------


def test_metrics_zero_interference_bitwise(served, tmp_path):
    """The same ragged greedy+sampled workload through metrics-on,
    metrics-off, and JSONL-logging engines must produce bitwise identical
    token streams — telemetry is a host-side observer."""
    cfg, _, _ = served

    def outputs(**eng_kw):
        eng = _engine(served, **eng_kw)
        rng = np.random.default_rng(5)
        states = []
        for i, p in enumerate(_prompts(cfg, rng, 5)):
            sampling = SamplingParams(greedy=(i % 2 == 0), temperature=0.9,
                                      top_k=16, seed=i)
            states.append(eng.submit(Request(
                prompt=tuple(p), max_new_tokens=int(rng.integers(2, 7)),
                sampling=sampling)))
        eng.run()
        return [s.output() for s in states]

    on = outputs()
    off = outputs(metrics=False)
    logged = outputs(metrics=EngineMetrics(
        log_path=str(tmp_path / "zi.jsonl")))
    assert on == off == logged


def test_disabled_metrics_hooks_are_inert(served):
    """metrics=False engines still expose a facade with a schema-clean
    (all-zero) snapshot, so operator code never branches."""
    eng = _engine(served, metrics=False)
    eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=3))
    eng.run()
    assert not eng.metrics.enabled
    snap = eng.metrics.snapshot()
    assert check_snapshot(snap) == []
    assert snap["counters"]["submitted"] == 0
    assert snap["elapsed_s"] == 0.0


# ---------------------------------------------------------------------------
# snapshot schema stability
# ---------------------------------------------------------------------------


def test_snapshot_schema_and_json_round_trip():
    clk = FakeClock()
    mx = EngineMetrics(clock=clk)
    mx.count("steps")
    mx.latency["ttft"].record(0.05)
    mx.sample_step(queue_depth=3, running=2, n_slots=4, free_blocks=7)
    clk.advance(1.0)
    snap = mx.snapshot()
    assert check_snapshot(snap) == []
    assert snap["schema_version"] == SCHEMA_VERSION
    assert snap["elapsed_s"] == pytest.approx(1.0)
    assert json.loads(mx.to_json()) == snap


def test_check_snapshot_flags_drift():
    snap = EngineMetrics(clock=FakeClock()).snapshot()
    assert check_snapshot(snap) == []

    missing = json.loads(json.dumps(snap))
    del missing["counters"]["steps"]
    assert any("counters.steps: missing" in p for p in check_snapshot(missing))

    extra = json.loads(json.dumps(snap))
    extra["latency_s"]["ttft"]["p75"] = 0.0
    assert any("unexpected field" in p for p in check_snapshot(extra))

    renamed = json.loads(json.dumps(snap))
    renamed["gauges"]["queue_len"] = renamed["gauges"].pop("queue_depth")
    assert len(check_snapshot(renamed)) >= 2  # missing + unexpected

    stale = json.loads(json.dumps(snap))
    stale["schema_version"] = SCHEMA_VERSION + 1
    assert any("schema_version" in p for p in check_snapshot(stale))


# ---------------------------------------------------------------------------
# backpressure attribution: refusal verdicts never go stale
# ---------------------------------------------------------------------------


def _queued(rid, prompt_len=4, priority=0):
    return RequestState(
        request=Request(prompt=tuple(range(1, prompt_len + 1)),
                        max_new_tokens=4, priority=priority),
        request_id=rid, arrival_t=0.0, submit_t=0.0)


def test_last_refusal_cleared_on_successful_admission():
    """Regression: a refusal verdict recorded for one queue head must not
    outlive a later successful admission — the engine turns
    ``last_refusal`` into the blocked_on_{blocks,budget} counters, so a
    stale verdict charges backpressure to a step where nothing blocked."""
    sched = Scheduler()
    a, b = _queued(0), _queued(1)
    sched.submit(a)
    sched.submit(b)
    # pool exhausted: the head is refused -> "resource" attribution
    assert sched.pop_admissions(2, can_admit=lambda s: False) == []
    assert sched.last_refusal == "resource"
    # pool recovered: admission succeeds and the old verdict is gone
    out = sched.pop_admissions(2, can_admit=lambda s: True)
    assert [s.request_id for s in out] == [0, 1]
    assert sched.last_refusal is None
    # mixed call: one admitted, then the new head refused — the verdict
    # describes the *current* head, not the earlier success
    c, d = _queued(2), _queued(3)
    sched.submit(c)
    sched.submit(d)
    assert sched.pop_admissions(2, can_admit=lambda s: s is c) == [c]
    assert sched.last_refusal == "resource"
    # draining the queue (no refusal at all) also leaves no verdict
    assert sched.pop_admissions(2, can_admit=lambda s: True) == [d]
    assert sched.last_refusal is None


def test_last_refusal_budget_verdict_not_sticky():
    """Same guarantee for the prefill-token budget: "budget" is reported
    on the step the budget bites and cleared on the step the deferred
    request actually gets in."""
    sched = Scheduler(max_prefill_tokens=6)
    sched.submit(_queued(0, prompt_len=5))
    sched.submit(_queued(1, prompt_len=5))
    out = sched.pop_admissions(2)
    assert [s.request_id for s in out] == [0]
    assert sched.last_refusal == "budget"
    out = sched.pop_admissions(2)
    assert [s.request_id for s in out] == [1]
    assert sched.last_refusal is None


# ---------------------------------------------------------------------------
# terminal-reason breakdown (schema v3): conservation across interleavings
# ---------------------------------------------------------------------------


def test_terminal_reason_conservation_across_interleavings(served):
    """After *every* step of a run that interleaves preemption (scarce
    overcommit pool), cancellation, and deadline expiry with normal
    finishes: ``submitted == finished + timed_out + cancelled + failed +
    in_flight``, with ``in_flight`` equal to the requests the harness can
    still see live — and the identity closes at zero in-flight when the
    engine drains."""
    cfg, _, _ = served
    clk = FakeClock()
    eng = _engine(served, n_slots=2, prefill_bucket=4, kv_block_size=8,
                  kv_pool_tokens=48, overcommit=True, clock=clk)
    rng = np.random.default_rng(9)
    prompts = _prompts(cfg, rng, 6, lo=3, hi=8)
    states = []
    for i, p in enumerate(prompts):
        states.append(eng.submit(Request(
            prompt=tuple(p), max_new_tokens=int(rng.integers(4, 12)),
            # every third request carries a deadline the advancing clock
            # will expire mid-run
            deadline_s=6.0 if i % 3 == 0 else None)))
    to_cancel = states[1]
    step = 0
    while eng.has_work():
        eng.step()
        step += 1
        clk.advance(1.0)
        if step == 3:
            assert eng.cancel(to_cancel.request_id)
        snap = eng.metrics.snapshot()
        term = snap["terminal"]
        c = snap["counters"]
        assert c["submitted"] == (term["finished"] + term["timed_out"]
                                  + term["cancelled"] + term["failed"]
                                  + term["in_flight"])
        live = sum(st.status not in ("finished", "timed_out", "cancelled",
                                     "failed") for st in states)
        assert term["in_flight"] == live
        # counters agree with the engine's own stats, reason by reason
        for key in ("finished", "timed_out", "cancelled", "failed"):
            assert term[key] == c[key] == eng.stats[key]
    term = eng.metrics.snapshot()["terminal"]
    assert term["in_flight"] == 0
    assert term["cancelled"] == 1
    assert term["timed_out"] >= 1            # the 6s deadlines expired
    assert eng.stats["preemptions"] >= 0     # scarce pool may preempt
    assert check_snapshot(eng.metrics.snapshot()) == []
    # goodput accounting: only eos/length completions feed tokens_finished
    c = eng.metrics.counters
    done_tokens = sum(len(st.tokens) for st in states
                      if st.status == "finished")
    assert c["tokens_finished"] == done_tokens

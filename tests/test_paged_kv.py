"""Paged int8 KV-cache tests: BlockPool mechanics, engine integration,
and the paged decode-attention kernel.

The load-bearing claim is bitwise equivalence: paged decode must emit
exactly the slot-row path's tokens on greedy ragged batches (the block
table is an addressing change, not a numerics change). Around that: the
allocation edges — pool exhaustion is clean admission backpressure (the
request stays queued, nothing crashes), retirement returns blocks for
immediate reuse, internal fragmentation stays under one block per live
request — and kernel-level proof (NaN poison) that the paged Pallas
index maps stream only mapped, valid blocks.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model
from repro.serving import BlockPool, Engine, Request
from repro.serving.paged import TRASH


@pytest.fixture(scope="module")
def served():
    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    return cfg, ctx, qp


def _engine(served, **kw):
    cfg, ctx, qp = served
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_bucket", 4)
    return Engine(qp, cfg, ctx, **kw)


def _prompts(cfg, rng, n, lo=3, hi=12):
    return [rng.integers(0, cfg.vocab_size, size=int(s)).tolist()
            for s in rng.integers(lo, hi, size=n)]


# ---------------------------------------------------------------------------
# bitwise parity: paged engine == contiguous slot-row engine
# ---------------------------------------------------------------------------


def test_paged_matches_contiguous_bitwise(served):
    """Ragged greedy workload through 2 slots (forced queueing + mid-run
    block reuse): the paged engine must emit exactly the slot-row
    engine's tokens, request for request."""
    cfg, _, _ = served
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng, 6)
    gens = [int(g) for g in rng.integers(2, 9, size=6)]

    def run(**kw):
        eng = _engine(served, **kw)
        sts = [eng.submit(Request(prompt=tuple(p), max_new_tokens=g))
               for p, g in zip(prompts, gens)]
        eng.run()
        assert eng.stats["transfers"] == eng.stats["device_steps"]
        return [s.output() for s in sts]

    assert run() == run(kv_block_size=8)


def test_paged_matches_contiguous_multi_horizon(served):
    """Same parity under multi-step scheduling (H=3): the horizon tail's
    garbage writes land in mapped (reserved) blocks, never a neighbor's."""
    cfg, _, _ = served
    rng = np.random.default_rng(1)
    prompts = _prompts(cfg, rng, 4)

    def run(**kw):
        eng = _engine(served, step_horizon=3, **kw)
        sts = [eng.submit(Request(prompt=tuple(p), max_new_tokens=5))
               for p in prompts]
        eng.run()
        return [s.output() for s in sts]

    assert run() == run(kv_block_size=8)


def test_paged_moe_family(served):
    cfg = tiny("moe")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=6).tolist()

    outs = []
    for kw in ({}, {"kv_block_size": 8}):
        eng = Engine(qp, cfg, ctx, n_slots=2, max_len=32,
                     prefill_bucket=4, **kw)
        st = eng.submit(Request(prompt=tuple(p), max_new_tokens=4))
        eng.run()
        outs.append(st.output())
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# allocation edges
# ---------------------------------------------------------------------------


def test_pool_exhaustion_is_clean_backpressure(served):
    """A pool too small for two concurrent requests admits one; the other
    stays queued (no crash, no partial admission) even though a SLOT is
    free, and completes after the first retires."""
    rng = np.random.default_rng(3)
    cfg, _, _ = served
    p1, p2 = _prompts(cfg, rng, 2, lo=4, hi=5)
    # 5 blocks of 8 = 40 tokens; each request needs 3 blocks (4-token
    # prompt + 18 new tokens -> 22 positions)
    eng = _engine(served, kv_block_size=8, kv_pool_tokens=40)
    a = eng.submit(Request(prompt=tuple(p1), max_new_tokens=18))
    b = eng.submit(Request(prompt=tuple(p2), max_new_tokens=18))
    eng.step()
    assert a.status == "running"
    assert b.status == "queued"          # blocked on blocks, not slots
    assert eng._slots.count(None) == 1   # a slot was free the whole time
    eng.run()
    assert a.status == b.status == "finished"
    assert len(a.output()) == len(b.output()) == 18

    # solo-parity through the backpressure path
    solo = _engine(served, kv_block_size=8)
    ref = solo.submit(Request(prompt=tuple(p2), max_new_tokens=18))
    solo.run()
    assert b.output() == ref.output()


def test_impossible_request_rejected_at_submit(served):
    eng = _engine(served, kv_block_size=8, kv_pool_tokens=16)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(Request(prompt=tuple(range(1, 9)), max_new_tokens=30))


def test_retire_then_admit_reuses_freed_blocks(served):
    """Blocks freed at retirement are handed to the next admission."""
    cfg, _, _ = served
    rng = np.random.default_rng(4)
    p1, p2 = _prompts(cfg, rng, 2, lo=4, hi=5)
    eng = _engine(served, n_slots=1, kv_block_size=8, kv_pool_tokens=32)
    a = eng.submit(Request(prompt=tuple(p1), max_new_tokens=4))
    eng.step()
    held_a = set(eng.pool.held(0))
    assert held_a
    eng.run()
    assert eng.pool.used_blocks == 0
    assert eng.pool.free_blocks == eng.pool.n_blocks
    b = eng.submit(Request(prompt=tuple(p2), max_new_tokens=4))
    eng.step()
    held_b = set(eng.pool.held(0))
    assert held_b & held_a  # freed physical blocks were reused
    eng.run()
    assert len(b.output()) == 4


def test_mid_block_waste_bounded(served):
    """Internal fragmentation: at every step a live request holds exactly
    ceil(frontier / block_size) blocks — under one block of waste — and
    a mid-block retirement returns everything."""
    cfg, _, _ = served
    bs = 8
    eng = _engine(served, n_slots=1, kv_block_size=bs)
    # prompt 3 (bucket-pads to 4), 9 new tokens: frontier ends mid-block
    st = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=9))
    eng.step()
    while st.status == "running":
        pos = int(eng._pos[0])  # tokens written so far (the frontier)
        held_tokens = len(eng.pool.held(0)) * bs
        assert held_tokens == max(-(-pos // bs), 1) * bs
        assert held_tokens - pos < bs  # waste < one block
        eng.step()
    assert st.finish_reason == "length"
    assert eng.pool.used_blocks == 0  # mid-block retirement freed it all


def test_trash_table_isolation(served):
    """After retirement the slot's table rows are all TRASH — the frozen
    row's garbage writes can never land in a reused block."""
    eng = _engine(served, n_slots=2, kv_block_size=8)
    st = eng.submit(Request(prompt=(5, 6, 7), max_new_tokens=3))
    eng.step()
    assert (eng.pool.table[0] != TRASH).any()
    eng.run()
    assert st.done
    assert (eng.pool.table == TRASH).all()


def test_paged_config_validation(served):
    cfg, ctx, qp = served
    with pytest.raises(ValueError, match="multiple of"):
        Engine(qp, cfg, ctx, n_slots=2, max_len=60, kv_block_size=8)
    # chunked prefill composes with paging since the paged attend_chunk
    # landed (the construction used to raise NotImplementedError)
    eng = Engine(qp, cfg, ctx, n_slots=2, max_len=64, kv_block_size=8,
                 prefill_chunk=4)
    assert eng.pool is not None and eng.prefill_chunk == 4
    scfg = tiny("ssm")
    sctx = ModelContext(cfg=scfg, remat=False)
    with pytest.raises(NotImplementedError, match="paged KV"):
        Engine({}, scfg, sctx, n_slots=2, max_len=32, kv_block_size=8)


def test_block_pool_reservation_accounting():
    pool = BlockPool(8, 4, n_slots=3, max_blocks=8)
    assert pool.n_phys == 9 and pool.free_blocks == 8
    assert pool.blocks_for(9) == 3
    pool.reserve(0, 5)
    assert pool.can_reserve(3) and not pool.can_reserve(4)
    pool.reserve(1, 3)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.reserve(2, 1)
    assert pool.ensure(0, 2)            # allocates on demand
    assert not pool.ensure(0, 2)        # idempotent
    assert pool.table[0, 0] != TRASH and pool.table[0, 1] != TRASH
    with pytest.raises(RuntimeError, match="reserved only"):
        pool.ensure(1, 4)               # beyond its reservation
    pool.release(0)
    assert pool.used_blocks == 0 and pool.can_reserve(5)
    with pytest.raises(ValueError, match="table width"):
        pool.reserve(2, 9)


# ---------------------------------------------------------------------------
# the paged decode-attention kernel
# ---------------------------------------------------------------------------


def _paged_fixture(seed=0, b=2, kvh=2, h=4, d=16, page=8, nb=4, n_phys=6):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, size=(n_phys, kvh, page, d)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(n_phys, kvh, page, d)),
                     jnp.int8)
    ks = jnp.asarray(rng.random((n_phys, kvh, page)) * 0.02, jnp.float32)
    vs = jnp.asarray(rng.random((n_phys, kvh, page)) * 0.02, jnp.float32)
    # row 0 maps blocks [1, 3], row 1 maps [2, 4, 5]; 0 is TRASH
    bt = jnp.asarray([[1, 3, 0, 0], [2, 4, 5, 0]], jnp.int32)
    length = jnp.asarray([10, 20], jnp.int32)

    def unpage(pool):
        g = pool[bt]
        if g.ndim == 5:
            return g.transpose(0, 2, 1, 3, 4).reshape(b, kvh, nb * page, d)
        return g.transpose(0, 2, 1, 3).reshape(b, kvh, nb * page)

    return q, (kp, vp, ks, vs), bt, length, unpage


def test_paged_kernel_matches_contiguous_kernel():
    """Same block_s -> identical S-sweep partition -> the paged kernel's
    output must be BITWISE the contiguous kernel's over the gathered
    cache (the table is pure addressing)."""
    from repro.kernels import ops as kops

    q, (kp, vp, ks, vs), bt, length, unpage = _paged_fixture()
    paged = kops.decode_attention(q, kp, vp, ks, vs, length=length,
                                  block_tables=bt, interpret=True,
                                  block_s=8)
    cont = kops.decode_attention(q, unpage(kp), unpage(vp), unpage(ks),
                                 unpage(vs), length=length,
                                 interpret=True, block_s=8)
    assert jnp.all(paged == cont)


def test_paged_jnp_fallback_matches_contiguous():
    """The gather-based jnp fallback (what CPU serving runs) is bitwise
    the contiguous jnp int8 path."""
    from repro.kernels import ops as kops

    q, (kp, vp, ks, vs), bt, length, unpage = _paged_fixture(seed=5)
    paged = kops.decode_attention(q, kp, vp, ks, vs, length=length,
                                  block_tables=bt, fused_dequant="int8")
    cont = kops.decode_attention(q, unpage(kp), unpage(vp), unpage(ks),
                                 unpage(vs), length=length,
                                 fused_dequant="int8")
    assert jnp.all(paged == cont)


def test_paged_kernel_streams_only_mapped_blocks():
    """NaN-poison TRASH and every unmapped physical block: the output must
    be bitwise unchanged — proof the scalar-prefetched index maps never
    let an unmapped block reach the compute loop."""
    from repro.kernels import ops as kops

    q, (kp, vp, ks, vs), bt, length, unpage = _paged_fixture()
    clean = kops.decode_attention(q, kp, vp, ks, vs, length=length,
                                  block_tables=bt, interpret=True,
                                  block_s=8)
    poison = jnp.full(ks.shape[1:], jnp.nan, jnp.float32)
    ks2, vs2 = ks.at[TRASH].set(poison), vs.at[TRASH].set(poison)
    out = kops.decode_attention(q, kp, vp, ks2, vs2, length=length,
                                block_tables=bt, interpret=True,
                                block_s=8)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert jnp.all(out == clean)


def test_paged_requires_length():
    from repro.kernels import ops as kops

    q, (kp, vp, ks, vs), bt, _, _ = _paged_fixture()
    with pytest.raises(ValueError, match="length"):
        kops.decode_attention(q, kp, vp, ks, vs, block_tables=bt)


def test_paged_block_s_tuning():
    """The paged block_s search only offers tiles that subdivide a page."""
    from repro.kernels import tuning

    cand = tuning.best_paged_decode_attn_block(4, 8, 4, 2048, 128, 256)
    assert 256 % cand.block_s == 0
    again = tuning.best_paged_decode_attn_block(4, 8, 4, 2048, 128, 256)
    assert cand is again  # cached per shape class

"""Flash-attention Pallas kernel + act_quant kernel vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.act_quant import act_quant_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import _flash_xla, decode_attention


def _qkv(rng, b, sq, skv, h, kvh, d, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(dtype)) * 0.3
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, d)).astype(dtype)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("b,s,h,kvh,d,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),    # MHA
    (2, 256, 8, 2, 64, 64, 128),   # GQA 4:1
    (2, 192, 8, 1, 32, 64, 64),    # MQA
    (1, 128, 4, 4, 128, 128, 32),  # wide head, small kv blocks
])
def test_flash_kernel_shape_sweep(rng, b, s, h, kvh, d, bq, bk):
    q, k, v = _qkv(rng, b, s, s, h, kvh, d)
    o_ref = R.flash_attention_ref(q, k, v, causal=True)
    o_pal = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_kernel_noncausal(rng):
    q, k, v = _qkv(rng, 2, 128, 128, 4, 2, 64)
    o_ref = R.flash_attention_ref(q, k, v, causal=False)
    o_pal = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                   block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_kernel_cross_lengths(rng):
    """Decode-style: short q against long kv with offset."""
    q, k, v = _qkv(rng, 2, 64, 256, 4, 4, 64)
    o_ref = R.flash_attention_ref(q, k, v, causal=True, q_offset=192)
    o_pal = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, q_offset=192, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_xla_path_matches_ref(rng):
    q, k, v = _qkv(rng, 2, 160, 160, 8, 2, 64)
    o_ref = R.flash_attention_ref(q, k, v, causal=True)
    o_xla = _flash_xla(q, k, v, True, 1 / 8.0, 0, block_k=64, block_q=64)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 128, 128, 4, 4, 64)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    o_ref = R.flash_attention_ref(q, k, v, causal=True)
    o_pal = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_decode_attention_int8_kv(rng):
    q, k, v = _qkv(rng, 2, 1, 256, 8, 4, 64)
    from repro.models.attention import quantize_kv_cached

    kq, ks, vq, vs = quantize_kv_cached(k, v)
    o = decode_attention(q, kq, vq, ks, vs,
                         length=jnp.full((2,), 256, jnp.int32))
    o_ref = R.flash_attention_ref(q, k, v, causal=True, q_offset=255)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=5e-2, atol=1e-2)  # int8 KV+attn budget


def test_decode_attention_length_mask(rng):
    """Entries past `length` must not contribute."""
    q, k, v = _qkv(rng, 1, 1, 64, 4, 4, 32)
    from repro.models.attention import quantize_kv_cached

    kq, ks, vq, vs = quantize_kv_cached(k, v)
    o_full = decode_attention(q, kq, vq, ks, vs,
                              length=jnp.asarray([32]))
    # poison the masked tail (seq axis 2 in cache layout); output unchanged
    kq2 = kq.at[:, :, 32:].set(127)
    vq2 = vq.at[:, :, 32:].set(127)
    o_poison = decode_attention(q, kq2, vq2, ks, vs,
                                length=jnp.asarray([32]))
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_poison))


@pytest.mark.parametrize("m,d", [(4, 64), (33, 128), (256, 32)])
def test_act_quant_kernel(rng, m, d):
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)) * 5
    q_ref, s_ref = R.act_quant_ref(x)
    q_pal, s_pal = act_quant_pallas(x, block_m=16, interpret=True)
    assert np.array_equal(np.asarray(q_pal), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), rtol=1e-6)

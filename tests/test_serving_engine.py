"""Continuous-batching engine tests.

The load-bearing claim is the slot/cache contract (see
`repro.serving.engine`): a request's token stream is bitwise identical
whatever the other slots hold — so a ragged mixed-arrival workload must
reproduce, token for token, a sequential one-request-at-a-time oracle and
(for greedy, bucket-exact prompts) the legacy static scan. Around that:
admission into freed slots mid-run, EOS retirement, slot-exhaustion
queueing, per-request sampling-param isolation, top-p behavior, chunked
prefill, and the one-device→host-transfer-per-step discipline.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model
from repro.serving import Engine, Request, SamplingParams, Scheduler


@pytest.fixture(scope="module")
def served():
    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    return cfg, ctx, qp


def _engine(served, **kw):
    cfg, ctx, qp = served
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_bucket", 4)
    return Engine(qp, cfg, ctx, **kw)


def _prompts(cfg, rng, n, lo=3, hi=12):
    return [rng.integers(0, cfg.vocab_size, size=int(s)).tolist()
            for s in rng.integers(lo, hi, size=n)]


# ---------------------------------------------------------------------------
# bitwise parity: ragged mixed arrivals vs sequential oracle / legacy scan
# ---------------------------------------------------------------------------


def test_ragged_engine_matches_sequential_oracle(served):
    """6 requests with ragged prompt and generation lengths through 2
    slots (forced queueing + mid-run slot reuse) must emit exactly the
    tokens each request gets when it runs alone."""
    cfg, _, _ = served
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng, 6)
    gens = [int(g) for g in rng.integers(2, 9, size=6)]

    eng = _engine(served)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=g))
              for p, g in zip(prompts, gens)]
    eng.run()
    outs = [s.output() for s in states]
    assert [len(o) for o in outs] == gens
    assert all(s.finish_reason == "length" for s in states)

    for p, g, out in zip(prompts, gens, outs):
        solo = _engine(served)
        st = solo.submit(Request(prompt=tuple(p), max_new_tokens=g))
        solo.run()
        assert st.output() == out  # bitwise: batchmates don't exist


def test_engine_matches_legacy_scan_greedy(served):
    """Greedy engine decode == the static `lm.generate_tokens` scan for the
    same single prompt (prefill_bucket=1: identical prefill geometry)."""
    cfg, ctx, qp = served
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, size=7).tolist()

    eng = _engine(served, prefill_bucket=1)
    st = eng.submit(Request(prompt=tuple(p), max_new_tokens=6))
    eng.run()

    logits, cache = lm.prefill(qp, jnp.asarray([p]), cfg, ctx, max_len=64)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    gen, _ = lm.generate_tokens(qp, cache, first, 6, cfg, ctx)
    assert st.output() == np.asarray(gen)[:, 0, 0].tolist()


def test_bucketed_prefill_is_exact(served):
    """Right-padding the prompt to the bucket must not change the tokens
    (causality: the valid prefix never sees the padded tail)."""
    cfg, _, _ = served
    p = list(range(1, 8))  # len 7 -> bucket pads to 8
    outs = []
    for bucket in (1, 4, 16):
        eng = _engine(served, prefill_bucket=bucket)
        st = eng.submit(Request(prompt=tuple(p), max_new_tokens=5))
        eng.run()
        outs.append(st.output())
    assert outs[0] == outs[1] == outs[2]


# ---------------------------------------------------------------------------
# admission / retirement mechanics
# ---------------------------------------------------------------------------


def test_admission_into_freed_slot_mid_run(served):
    """With 2 slots and a short + long + queued request, the queued one
    must be admitted into the short one's slot while the long one is still
    decoding — and still match its solo tokens."""
    cfg, _, _ = served
    rng = np.random.default_rng(2)
    short, long_, queued = _prompts(cfg, rng, 3)

    eng = _engine(served)
    s1 = eng.submit(Request(prompt=tuple(short), max_new_tokens=2))
    s2 = eng.submit(Request(prompt=tuple(long_), max_new_tokens=12))
    s3 = eng.submit(Request(prompt=tuple(queued), max_new_tokens=4))
    eng.step()
    slot1 = s1.slot
    # s3 queued (both slots busy)
    assert len(eng.scheduler) == 1 and s3.status == "queued"
    while s1.status != "finished":
        eng.step()
    # retirement and admission happen in the same host step: the freed slot
    # admits s3 while s2 is still mid-decode
    assert s2.status == "running"
    assert s3.status == "running" and s3.slot == slot1
    eng.run()

    solo = _engine(served)
    st = solo.submit(Request(prompt=tuple(queued), max_new_tokens=4))
    solo.run()
    assert s3.output() == st.output()


def test_eos_retirement_and_slot_reuse(served):
    """A row that emits its stop token retires immediately (freeing the
    slot) and reports finish_reason='eos'; outputs end at the stop token."""
    cfg, _, _ = served
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, size=6).tolist()

    ref = _engine(served)
    st = ref.submit(Request(prompt=tuple(p), max_new_tokens=8))
    ref.run()
    full = st.output()
    eos = full[2]  # stop on the third emitted token

    eng = _engine(served)
    st2 = eng.submit(Request(prompt=tuple(p), max_new_tokens=8, eos_id=eos))
    eng.run()
    assert st2.finish_reason == "eos"
    assert st2.output() == full[:3]
    assert st2.output(strip_eos=True) == full[:2]
    # engine idle again: all slots free
    assert not eng.has_work()
    # the retired slot did strictly fewer device steps than max_new_tokens
    assert eng.stats["device_steps"] < 8 + 2


def test_slot_exhaustion_queues_fifo(served):
    """More requests than slots: the overflow waits in the scheduler and
    every request still completes with its full budget."""
    cfg, _, _ = served
    rng = np.random.default_rng(4)
    prompts = _prompts(cfg, rng, 5, lo=3, hi=6)
    eng = _engine(served)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=3))
              for p in prompts]
    assert len(eng.scheduler) == 5  # nothing admitted before step()
    eng.step()
    assert len(eng.scheduler) == 3  # 2 slots filled
    running = [s for s in states if s.status == "running"]
    assert [s.request_id for s in running] == [0, 1]  # FIFO
    eng.run()
    assert all(len(s.output()) == 3 for s in states)


def test_priority_admission(served):
    cfg, _, _ = served
    rng = np.random.default_rng(5)
    prompts = _prompts(cfg, rng, 3, lo=3, hi=6)
    eng = _engine(served, n_slots=1)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=2,
                                 priority=pr))
              for p, pr in zip(prompts, (5, 1, 3))]
    eng.run()
    order = sorted(states, key=lambda s: s.finish_t)
    assert [s.request_id for s in order] == [1, 2, 0]


def test_prefill_token_budget_defers_admission(served):
    """A per-step prefill budget admits the first request but defers the
    second to a later step — running decodes aren't stalled by a wall of
    prefill work."""
    cfg, _, _ = served
    rng = np.random.default_rng(6)
    prompts = _prompts(cfg, rng, 2, lo=10, hi=12)
    eng = _engine(served, scheduler=Scheduler(max_prefill_tokens=12))
    a = eng.submit(Request(prompt=tuple(prompts[0]), max_new_tokens=3))
    b = eng.submit(Request(prompt=tuple(prompts[1]), max_new_tokens=3))
    eng.step()
    assert a.status == "running" and b.status == "queued"
    eng.step()
    assert b.status == "running"
    eng.run()
    assert len(a.output()) == 3 and len(b.output()) == 3


# ---------------------------------------------------------------------------
# sampling: per-request isolation, top-p
# ---------------------------------------------------------------------------


def test_sampling_param_isolation(served):
    """A sampled request's stream depends only on (seed, step): same
    request, totally different batchmates → identical tokens."""
    cfg, _, _ = served
    rng = np.random.default_rng(7)
    p = rng.integers(0, cfg.vocab_size, size=6).tolist()
    sp = SamplingParams(greedy=False, temperature=0.8, top_k=16, top_p=0.9,
                        seed=42)

    def run_with(others):
        eng = _engine(served, n_slots=3)
        st = eng.submit(Request(prompt=tuple(p), max_new_tokens=8,
                                sampling=sp))
        for q, g, s in others:
            eng.submit(Request(prompt=tuple(q), max_new_tokens=g,
                               sampling=SamplingParams(greedy=False, seed=s)))
        eng.run()
        return st.output()

    alone = run_with([])
    crowd = run_with([(pp, int(g), i) for i, (pp, g) in enumerate(
        zip(_prompts(cfg, rng, 4), rng.integers(2, 10, size=4)))])
    assert alone == crowd
    # different seed -> different stream (overwhelmingly)
    other = _engine(served, n_slots=3)
    st2 = other.submit(Request(
        prompt=tuple(p), max_new_tokens=8,
        sampling=SamplingParams(greedy=False, temperature=0.8, top_k=16,
                                top_p=0.9, seed=43)))
    other.run()
    assert st2.output() != alone


def test_mixed_greedy_and_sampled_rows(served):
    """Greedy and sampled rows share one compiled step; the greedy row
    must stay bitwise-greedy while its neighbor samples."""
    cfg, _, _ = served
    rng = np.random.default_rng(8)
    p = rng.integers(0, cfg.vocab_size, size=5).tolist()
    solo = _engine(served)
    ref = solo.submit(Request(prompt=tuple(p), max_new_tokens=6))
    solo.run()

    eng = _engine(served)
    g = eng.submit(Request(prompt=tuple(p), max_new_tokens=6))
    eng.submit(Request(prompt=tuple(p), max_new_tokens=6,
                       sampling=SamplingParams(greedy=False, temperature=1.5,
                                               seed=3)))
    eng.run()
    assert g.output() == ref.output()


def test_top_p_distribution_sanity(key):
    """Nucleus sampling over a known distribution: top_p=0.5 on a
    [0.45, 0.35, 0.1, ...] softmax keeps exactly the two head tokens
    (0.45 < 0.5 → the second is the crossing token, kept; mass before the
    third is 0.8 ≥ 0.5 → dropped)."""
    from repro.models.lm import sample_logits, sample_logits_ragged

    probs = np.array([0.45, 0.35, 0.1, 0.06, 0.04], np.float32)
    logits = jnp.log(jnp.asarray(probs))[None, None, :]
    draws = set()
    for i in range(64):
        t = sample_logits(logits, jax.random.fold_in(key, i), top_p=0.5)
        draws.add(int(t[0, 0]))
    assert draws == {0, 1}

    # per-row vector form: row0 p=0.5 (2 tokens), row1 p=0.95 (4 tokens),
    # row2 p=0.0 (filter off: all 5 reachable)
    lf = jnp.broadcast_to(logits, (3, 1, 5))
    per_row = [set() for _ in range(3)]
    for i in range(200):
        keys = jax.vmap(lambda s: jax.random.fold_in(
            jax.random.fold_in(key, s), i))(jnp.arange(3))
        t = sample_logits_ragged(
            lf, keys, temperature=jnp.ones(3), top_k=jnp.zeros(3, jnp.int32),
            top_p=jnp.asarray([0.5, 0.95, 0.0]))
        for r in range(3):
            per_row[r].add(int(t[r, 0]))
    assert per_row[0] == {0, 1}
    assert per_row[1] == {0, 1, 2, 3}
    assert per_row[2] == {0, 1, 2, 3, 4}


def test_top_p_composes_with_top_k(key):
    """top_k=2 then top_p=0.99: the nucleus re-normalizes over the top-2
    support, so only {0, 1} survive even though p would admit more."""
    from repro.models.lm import sample_logits

    probs = np.array([0.3, 0.25, 0.2, 0.15, 0.1], np.float32)
    logits = jnp.log(jnp.asarray(probs))[None, None, :]
    draws = set()
    for i in range(64):
        t = sample_logits(logits, jax.random.fold_in(key, i), top_k=2,
                          top_p=0.99)
        draws.add(int(t[0, 0]))
    assert draws == {0, 1}


def test_static_ragged_batch_matches_solo_and_engine():
    """The static batcher's per-row last_pos/positions fix: a short row in
    a ragged batch samples its first token from ITS prompt end (not the
    right-pad tail) and never attends pad KV — so each row matches its
    solo run, and the static path matches the engine path bitwise."""
    from repro.launch.serve import Server

    server = Server(arch="qwen3-4b", smoke=True, w_bits=4, max_len=64)
    rng = np.random.default_rng(12)
    long_p = rng.integers(0, server.cfg.vocab_size, size=11).tolist()
    short_p = rng.integers(0, server.cfg.vocab_size, size=3).tolist()
    ragged, _ = server.generate([long_p, short_p], max_new_tokens=6)
    solo_long, _ = server.generate([long_p], max_new_tokens=6)
    solo_short, _ = server.generate([short_p], max_new_tokens=6)
    assert ragged[0] == solo_long[0]
    assert ragged[1] == solo_short[0]
    eng, _ = server.generate([long_p, short_p], max_new_tokens=6,
                             engine=True)
    assert eng == ragged


def test_server_generate_top_p_and_eos():
    """The legacy scan path carries top_p and eos through jit."""
    from repro.launch.serve import Server

    server = Server(arch="qwen3-4b", smoke=True, w_bits=4, max_len=64)
    kw = dict(max_new_tokens=5, greedy=False, temperature=0.8, top_p=0.9)
    o1, _ = server.generate([[1, 2, 3], [4, 5]], seed=7, **kw)
    o2, _ = server.generate([[1, 2, 3], [4, 5]], seed=7, **kw)
    assert o1 == o2
    assert all(0 <= t < server.cfg.vocab_size for o in o1 for t in o)
    # eos: pick the greedy stream's second token, expect a trimmed output
    g, _ = server.generate([[1, 2, 3]], max_new_tokens=5)
    eos = g[0][1]
    o3, _ = server.generate([[1, 2, 3]], max_new_tokens=5, eos_id=eos)
    assert o3[0] == g[0][:2]


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_interleaves_and_completes(served):
    """A long prompt fed chunk-by-chunk must not stall the running row:
    decode steps happen between its chunks, and it still generates its
    full budget."""
    cfg, _, _ = served
    rng = np.random.default_rng(9)
    runner_p = rng.integers(0, cfg.vocab_size, size=3).tolist()
    long_p = rng.integers(0, cfg.vocab_size, size=13).tolist()

    eng = _engine(served, prefill_chunk=3)
    runner = eng.submit(Request(prompt=tuple(runner_p), max_new_tokens=12))
    eng.step()  # runner admitted + decoding
    long_st = eng.submit(Request(prompt=tuple(long_p), max_new_tokens=4))
    tokens_before = None
    while long_st.status in ("queued", "prefilling"):
        eng.step()
        if long_st.status == "prefilling" and tokens_before is None:
            tokens_before = len(runner.tokens)
    # the runner kept decoding while the long prompt prefilled
    assert len(runner.tokens) > (tokens_before or 0)
    eng.run()
    assert len(long_st.output()) == 4
    assert eng.stats["prefill_chunks"] == 5  # ceil(13 / 3)
    # chunked prefill of a short prompt (<= chunk) takes the exact path
    assert runner.output() and len(runner.output()) == 12

    # regression oracle: the interleaved run must match a solo chunked run
    # bitwise — decode steps running *between* the long prompt's chunks
    # write (discarded) KV at the prefilling row's frontier; a stale
    # frontier would let those writes corrupt already-prefilled positions
    solo = _engine(served, prefill_chunk=3)
    ref = solo.submit(Request(prompt=tuple(long_p), max_new_tokens=4))
    solo.run()
    assert long_st.output() == ref.output()


def test_chunked_prefill_rejected_for_ssm(served):
    cfg = tiny("ssm")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=8, a_bits=8))
    with pytest.raises(NotImplementedError, match="chunked prefill"):
        Engine(qp, cfg, ctx, n_slots=2, max_len=32, prefill_chunk=4)


# ---------------------------------------------------------------------------
# transfer discipline & non-attention families
# ---------------------------------------------------------------------------


def test_one_device_to_host_transfer_per_step(served, monkeypatch):
    """Each engine step makes exactly one device→host transfer (the token
    snapshot) — admission, prefill and decode stay on device."""
    import repro.serving.engine as engine_mod

    eng = _engine(served)
    transfers = {"n": 0}
    orig = np.asarray

    def counting_asarray(a, *args, **kw):
        if isinstance(a, jax.Array):
            transfers["n"] += 1
        return orig(a, *args, **kw)

    monkeypatch.setattr(engine_mod.np, "asarray", counting_asarray)
    rng = np.random.default_rng(10)
    for p in _prompts(tiny("dense"), rng, 4):
        eng.submit(Request(prompt=tuple(p), max_new_tokens=5))
    eng.run()
    assert transfers["n"] == eng.stats["transfers"]
    assert eng.stats["transfers"] == eng.stats["device_steps"]
    assert eng.stats["transfers"] < eng.stats["steps"] + 1


def test_engine_ssm_family():
    """The slot pool generalizes to recurrent caches (state rows instead
    of pos-indexed KV): ragged batch == sequential oracle there too."""
    cfg = tiny("ssm")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=8, a_bits=8))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(s)).tolist()
               for s in rng.integers(3, 8, size=3)]

    eng = Engine(qp, cfg, ctx, n_slots=2, max_len=32)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=3))
              for p in prompts]
    eng.run()
    for p, st in zip(prompts, states):
        solo = Engine(qp, cfg, ctx, n_slots=2, max_len=32)
        ref = solo.submit(Request(prompt=tuple(p), max_new_tokens=3))
        solo.run()
        assert st.output() == ref.output()


def test_engine_rejects_unsupported_family():
    cfg = tiny("vlm")
    ctx = ModelContext(cfg=cfg, remat=False)
    with pytest.raises(NotImplementedError, match="continuous batching"):
        Engine({}, cfg, ctx, n_slots=2, max_len=32)


def test_submit_validates_budget(served):
    eng = _engine(served, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=tuple(range(1, 10)), max_new_tokens=12))


# ---------------------------------------------------------------------------
# decode-attn autotune measure hook (satellite)
# ---------------------------------------------------------------------------


def test_best_decode_attn_block_measure_callable():
    from repro.kernels import tuning

    seen = []

    def measure(bs):
        seen.append(bs)
        return abs(bs - 512)  # prefer 512 against the model's pick

    cand = tuning.best_decode_attn_block(4, 8, 4, 2048, 128, measure=measure)
    assert cand.block_s == 512
    # search stayed inside kernel-legal space, and tried > 1 candidate
    assert all(2048 % b == 0 for b in seen) and len(seen) > 1
    # modeled path still cached (measure results are not)
    a = tuning.best_decode_attn_block(4, 8, 4, 2048, 128)
    b = tuning.best_decode_attn_block(4, 8, 4, 2048, 128)
    assert a is b
    assert a.block_s in (128, 256, 512, 1024, 2048)


# ---------------------------------------------------------------------------
# preemption + optimistic overcommit
# ---------------------------------------------------------------------------


def test_overcommit_requires_paged_pool(served):
    with pytest.raises(ValueError, match="overcommit"):
        _engine(served, overcommit=True)


def test_overcommit_admits_beyond_worst_case_reservation(served):
    """Two requests whose combined worst-case exceeds the pool: the
    conservative gate serializes them; overcommit runs them concurrently
    (their *actual* footprints fit) without a single preemption."""
    # each request worst-case: 8 prompt-extent + 24 budget -> 4 blocks of
    # 8; pool of 6 blocks fits one worst case, not two
    reqs = [Request(prompt=tuple(range(1, 8)), max_new_tokens=24,
                    eos_id=None) for _ in range(2)]
    conservative = _engine(served, kv_block_size=8, kv_pool_tokens=48)
    for r in reqs:
        conservative.submit(r)
    conservative.run()
    assert conservative.stats["peak_running"] == 1  # serialized

    over = _engine(served, kv_block_size=8, kv_pool_tokens=48,
                   overcommit=True)
    states = [over.submit(r) for r in reqs]
    over.run()
    assert over.stats["peak_running"] == 2  # concurrent at equal budget
    # both rows eventually want 4 blocks each (31 positions) against 6
    # total, so the safety valve must fire — and both must still finish
    # with their full budget of tokens
    assert over.stats["preemptions"] > 0
    for st in states:
        assert len(st.output()) == 24


def test_preempt_churn_matches_sequential_oracle(served):
    """The satellite churn oracle: seeded random arrivals, lengths,
    priorities and EOS through a deliberately undersized pool with paged
    + chunked + overcommit on. Every request completes, preemptions
    actually happen (including mid-generation), and every output —
    preempted-and-resumed or not — is bitwise equal to the request run
    alone on a roomy engine."""
    cfg, _, _ = served
    rng = np.random.default_rng(7)
    eos = 5  # tiny vocab: greedy streams hit it organically
    reqs = []
    for i in range(10):
        p = tuple(int(t) for t in
                  rng.integers(0, cfg.vocab_size,
                               size=int(rng.integers(3, 14))))
        sp = SamplingParams() if i % 3 else SamplingParams(
            greedy=False, temperature=0.8, top_k=8, seed=100 + i)
        reqs.append(Request(prompt=p,
                            max_new_tokens=int(rng.integers(6, 20)),
                            eos_id=eos, sampling=sp,
                            priority=int(rng.integers(0, 2))))

    oracle = []
    for r in reqs:
        solo = _engine(served, kv_block_size=8, prefill_chunk=4)
        st = solo.submit(r)
        solo.run()
        oracle.append(st.output())

    # 4 slots x 64 max_len but only 6 blocks of 8 = 48 pool tokens, and
    # arrivals staggered so admission interleaves with running decodes
    eng = _engine(served, n_slots=4, kv_block_size=8, kv_pool_tokens=48,
                  prefill_chunk=4, step_horizon=2, overcommit=True)
    arrive = sorted(int(s) for s in rng.integers(0, 12, size=len(reqs)))
    states, pending = [], list(zip(arrive, reqs))
    step = 0
    while pending or eng.has_work():
        while pending and pending[0][0] <= step:
            states.append(eng.submit(pending.pop(0)[1]))
        eng.step()
        step += 1
        assert step < 2000, "engine failed to drain"

    assert eng.stats["preemptions"] > 0, "undersized pool never preempted"
    # at least one victim was mid-generation: its snapshot was replayed
    assert eng.stats["replayed_tokens"] > 0
    assert any(st.preempt_count > 0 for st in states)
    for st, ora in zip(states, oracle):
        assert st.done
        assert st.output() == ora  # bitwise, preempted or not
    # fairness bound held
    assert all(st.preempt_count <= eng.preempt_limit + eng.n_slots
               for st in states)


def test_preemption_no_deadlock_no_starvation(served):
    """Heavy-tailed load: a few long requests *claim* worst cases that in
    sum dwarf the pool, while most requests are short — so worst-case
    admission would serialize everything but typical demand fits. The
    engine must keep making forward progress every k steps, drain
    completely, and bound every request's preemption count."""
    cfg, _, _ = served
    rng = np.random.default_rng(11)
    reqs = []
    for i in range(12):
        p = tuple(int(t) for t in
                  rng.integers(0, cfg.vocab_size,
                               size=int(rng.integers(3, 10))))
        # every 4th request wants 40 new tokens (5+ blocks; together the
        # three long ones over-claim half the 6-block pool each), the
        # rest are short — the heavy tail the optimistic pool exploits
        budget = 40 if i % 4 == 0 else int(rng.integers(2, 7))
        reqs.append(Request(prompt=p, max_new_tokens=budget))

    eng = _engine(served, n_slots=4, kv_block_size=8, kv_pool_tokens=48,
                  prefill_chunk=4, overcommit=True)
    states = [eng.submit(r) for r in reqs]

    def progress():
        return (eng.stats["finished"], eng.stats["tokens_out"],
                eng.stats["replayed_tokens"], eng.stats["prefill_chunks"],
                eng.stats["admitted"])

    k = 12  # a replay of the longest snapshot fits well inside this
    last, stale = progress(), 0
    for step in range(4000):
        if not eng.has_work():
            break
        eng.step()
        cur = progress()
        stale = stale + 1 if cur == last else 0
        last = cur
        assert stale < k, f"no forward progress for {k} steps at {step}"
    assert not eng.has_work(), "engine deadlocked"
    assert all(st.done for st in states)
    assert all(st.finish_reason in ("eos", "length") for st in states)
    # bounded preemption per request: no one was starved by churn
    assert all(st.preempt_count <= eng.preempt_limit + eng.n_slots
               for st in states)


def test_preempted_tokens_never_mutate_after_streaming(served):
    """Clients hold references to ``st.tokens`` while the engine runs;
    preemption+resume must only ever append — never rewrite — the
    streamed prefix."""
    cfg, _, _ = served
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=6)),
                    max_new_tokens=14) for _ in range(6)]
    eng = _engine(served, n_slots=3, kv_block_size=8, kv_pool_tokens=40,
                  prefill_chunk=4, overcommit=True)
    states = [eng.submit(r) for r in reqs]
    seen = {st.request_id: [] for st in states}
    steps = 0
    while eng.has_work():
        eng.step()
        steps += 1
        assert steps < 2000
        for st in states:
            prefix = seen[st.request_id]
            assert st.tokens[: len(prefix)] == prefix  # append-only
            seen[st.request_id] = list(st.tokens)
    assert eng.stats["preemptions"] > 0

import os

# Tests run on the real (single-CPU) device topology. Only the dry-run and
# the dedicated sharding tests use placeholder devices, in subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchConfig


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


TINY = {
    "dense": ArchConfig(name="t-dense", family="dense", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                        qk_norm=True),
    "moe": ArchConfig(name="t-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                      n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=64),
    "ssm": ArchConfig(name="t-ssm", family="ssm", n_layers=3, d_model=64,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=256,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8),
    "hybrid": ArchConfig(name="t-hybrid", family="hybrid", n_layers=5,
                         d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                         vocab_size=256, ssm_state=16, ssm_headdim=16,
                         ssm_chunk=8, shared_attn_every=2),
    "vlm": ArchConfig(name="t-vlm", family="vlm", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      cross_attn_every=2, n_image_tokens=8),
    "audio": ArchConfig(name="t-audio", family="audio", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                        n_codebooks=4),
}


@pytest.fixture(params=list(TINY))
def tiny_cfg(request):
    cfg = TINY[request.param]
    cfg.validate()
    return cfg


def tiny(family: str) -> ArchConfig:
    return TINY[family]

"""Unit tests: quantization grids, bit balance, packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec,
    act_scales,
    dequantize_weight,
    fake_quant_act,
    fake_quant_weight,
    pack_weight,
    quantize_act,
    quantize_weight,
    unpack_levels,
    weight_scales,
)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_weight_roundtrip_error_bound(rng, bits):
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    spec = QuantSpec(bits=bits, granularity="per_channel", channel_axis=1)
    scale, zp = weight_scales(w, spec)
    q = quantize_weight(w, scale, zp, spec)
    wd = dequantize_weight(q, scale, zp, spec)
    # uniform quantizer: max error <= scale/2 within the clip range
    assert np.all(np.abs(np.asarray(wd - w)) <= np.asarray(scale) / 2 + 1e-6)


def test_bit_balance_levels():
    """W2* must hit the symmetric level set {-2,-1,0,1,2} (paper §3.3)."""
    spec = QuantSpec(bits=2, bit_balance=True)
    assert spec.num_levels == 5
    assert spec.qmax_abs == 2
    assert spec.storage_bits == 3
    w = jnp.asarray(np.linspace(-1, 1, 101, dtype=np.float32).reshape(-1, 1))
    scale, zp = weight_scales(w, spec)
    q = quantize_weight(w, scale, zp, spec)
    signed = np.asarray(q) - float(zp[0, 0])
    assert set(np.unique(signed)) <= {-2, -1, 0, 1, 2}
    # symmetric input -> symmetric quantized histogram
    hist = {v: int(np.sum(signed == v)) for v in (-2, -1, 1, 2)}
    assert hist[-2] == hist[2] and hist[-1] == hist[1]


def test_standard_int2_is_asymmetric():
    """Plain INT2 has only 4 levels — the asymmetry bit balance fixes."""
    spec = QuantSpec(bits=2, symmetric=True)
    assert spec.num_levels == 4
    assert spec.qmax_abs == 1  # {-1, 0, 1} effective after symmetric clamp


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_act_quant_per_token(rng, bits):
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32)) * 3
    spec = QuantSpec(bits=bits, symmetric=True, granularity="per_token")
    s = act_scales(x, spec)
    q = quantize_act(x, s, spec)
    assert q.dtype == jnp.int8
    xd = np.asarray(q, np.float32) * np.asarray(s)
    assert np.max(np.abs(xd - np.asarray(x))) <= float(np.max(s)) / 2 + 1e-6


def test_fake_quant_weight_gradients():
    """STE: gradients flow to w and to the clipping params."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32)
    spec = QuantSpec(bits=4)
    alpha = jnp.full((8,), 0.9)
    beta = jnp.full((8,), 0.9)

    def loss(w_, a_, b_):
        return jnp.sum(jnp.square(fake_quant_weight(w_, spec, a_, b_)))

    gw, ga, gb = jax.grad(loss, argnums=(0, 1, 2))(w, alpha, beta)
    assert np.isfinite(np.asarray(gw)).all()
    assert float(jnp.sum(jnp.abs(ga))) > 0
    assert float(jnp.sum(jnp.abs(gb))) > 0


def test_per_group_quantization(rng):
    w = jnp.asarray(rng.normal(size=(256, 16)).astype(np.float32))
    # one outlier group should not poison the others' scales
    w = w.at[:128].mul(10.0)
    spec_pc = QuantSpec(bits=4, granularity="per_channel", channel_axis=1)
    spec_pg = QuantSpec(bits=4, granularity="per_group", group_size=128)
    def err(spec):
        sc, zp = weight_scales(w, spec)
        q = quantize_weight(w, sc, zp, spec)
        return float(jnp.mean(jnp.square(dequantize_weight(q, sc, zp, spec) - w)[128:]))
    assert err(spec_pg) < err(spec_pc) / 4  # g128 isolates the outlier rows


@pytest.mark.parametrize("bits,bb", [(2, False), (2, True), (3, False), (8, False)])
def test_pack_weight_levels_roundtrip(rng, bits, bb):
    w = jnp.asarray(rng.normal(size=(96, 16)).astype(np.float32))
    spec = QuantSpec(bits=bits, bit_balance=bb)
    pw = pack_weight(w, spec)
    sc, zp = weight_scales(w, spec)
    q = quantize_weight(w, sc, zp, spec)
    lv = unpack_levels(pw.planes, 96)
    assert np.array_equal(np.asarray(q), np.asarray(lv))
    assert pw.n_planes == spec.storage_bits

"""End-to-end training, fault tolerance, elasticity (deliverables b/c).

These drive the real CLI entry points (repro.launch.train / supervisor) on
CPU-sized smoke configs.
"""

import os

import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.launch.supervisor import supervise


def _args(tmp_path, extra=()):
    return [
        "--arch", "llama-7b", "--smoke",
        "--steps", "12", "--global-batch", "4", "--seq-len", "32",
        "--checkpoint-dir", str(tmp_path / "ckpt"),
        "--checkpoint-every", "4",
        "--lr", "5e-3",
    ] + list(extra)


def test_train_loss_decreases(tmp_path):
    result = train_mod.run(_args(tmp_path))
    losses = result["losses"]
    assert len(losses) == 12
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Crash at step 8, resume from the step-8 checkpoint: the remaining
    steps must produce byte-identical losses to an uninterrupted run
    (deterministic data + atomic checkpoints)."""
    ref = train_mod.run(_args(tmp_path / "a"))

    with pytest.raises(RuntimeError, match="injected failure"):
        train_mod.run(_args(tmp_path / "b", ["--fail-at-step", "8"]))
    resumed = train_mod.run(_args(tmp_path / "b", ["--resume"]))

    # the resumed run starts at the last checkpoint (step 8) and must match
    np.testing.assert_allclose(resumed["losses"], ref["losses"][8:],
                               rtol=1e-5)


def test_supervisor_restarts_after_injected_failure(tmp_path):
    result = supervise(_args(tmp_path, ["--fail-at-step", "6"]),
                       max_restarts=2)
    assert result["restarts"] == 1
    assert len(result["losses"]) > 0  # completed after restart


def test_straggler_watch_flags_slow_steps():
    from repro.launch.train import StragglerWatch

    w = StragglerWatch(factor=3.0)
    for i in range(10):
        assert not w.record(i, 0.1)
    assert w.record(10, 1.0)  # 10x median -> flagged
    assert w.flagged == [10]
    assert not w.record(11, 0.12)


def test_microbatched_grads_match_full_batch(tmp_path, key=None):
    """Gradient accumulation must be equivalent to the full-batch gradient."""
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainConfig, make_train_step
    from repro.models import lm
    from repro.models.blocks import ModelContext

    cfg = get_smoke_config("llama-7b")
    ctx = ModelContext(cfg=cfg, remat=False)
    opt_cfg = optim.AdamWConfig(lr=1e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt_state = optim.init(params, opt_cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    outs = {}
    for mb in (1, 2):
        tcfg = TrainConfig(steps=10, microbatches=mb, grad_clip=0.0)
        step = make_train_step(cfg, tcfg, ctx, opt_cfg)
        new_p, _, _, metrics = step(params, opt_state, {}, batch,
                                    jnp.asarray(0))
        outs[mb] = (float(metrics["loss"]),
                    np.asarray(jax.tree.leaves(new_p)[0], np.float32))
    assert abs(outs[1][0] - outs[2][0]) < 1e-4
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-4, atol=1e-5)

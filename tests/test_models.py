"""Model-family behaviour tests: train/prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.blocks import ModelContext
from conftest import tiny


def _batch(cfg, key, b=2, s=32):
    ts = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    tokens = jax.random.randint(key, ts, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16) * 0.05
    return batch


def test_all_families_train_loss_finite(tiny_cfg, key):
    ctx = ModelContext(cfg=tiny_cfg, remat=True)
    params = lm.init_params(key, tiny_cfg)
    batch = _batch(tiny_cfg, key)
    loss, metrics = lm.loss_fn(params, batch, tiny_cfg, ctx, n_loss_chunks=4)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, batch, tiny_cfg, ctx)[0])(params)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


def test_all_families_prefill_decode(tiny_cfg, key):
    cfg = tiny_cfg
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, cache = lm.prefill(params, batch["tokens"], cfg, ctx, max_len=40,
                               image_embeds=batch.get("image_embeds"))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    nt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = lm.decode_step(params, cache, nt, cfg, ctx)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_prefill_decode_matches_full_forward(family, key):
    """Teacher-forced decode after prefill must agree with a single long
    forward pass (the cache carries exactly the right state)."""
    cfg = tiny(family)
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg, dtype=jnp.float32)
    b, s_total, s_prompt = 2, 24, 16
    tokens = jax.random.randint(key, (b, s_total), 0, cfg.vocab_size)

    # ground truth: last-position logits of the full forward at each step
    h_full, _ = lm.forward_hidden(params, tokens, cfg, ctx)
    from repro.models.layers import rms_norm
    from repro.models.loss import logits_last_token

    h_full = rms_norm(h_full, params["final_norm"], cfg.norm_eps)
    full_logits = [
        logits_last_token(h_full[:, t:t + 1], lm.lm_head_weight(params, cfg),
                          ctx.shard)
        for t in range(s_prompt - 1, s_total - 1)
    ]

    logits, cache = lm.prefill(params, tokens[:, :s_prompt], cfg, ctx,
                               max_len=s_total + 1)
    outs = [logits]
    for t in range(s_prompt, s_total - 1):
        logits, cache = lm.decode_step(params, cache, tokens[:, t:t + 1],
                                       cfg, ctx)
        outs.append(logits)

    for i, (a, b_) in enumerate(zip(outs, full_logits)):
        diff = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b_.astype(jnp.float32))))
        # int8 KV cache introduces small error for attention families
        tol = 0.15 if family in ("dense", "hybrid") else 2e-2
        assert diff < tol, f"{family} step {i}: decode/forward diverged {diff}"


def test_moe_routing_covers_topk(key):
    from repro.models import moe as moe_mod

    cfg = tiny("moe")
    params = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_mod.moe_ffn(params, x, cfg, mesh=None)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss is >= 1 for any routing (E * sum(me*ce) >= 1 at balance)
    assert float(aux) > 0.5


def test_moe_capacity_drop_is_graceful(key):
    """With capacity_factor near zero most tokens drop; output stays finite
    and shrinks toward the shared-expert-only contribution."""
    import dataclasses

    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(tiny("moe"), capacity_factor=0.01)
    params = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, _ = moe_mod.moe_ffn(params, x, cfg, mesh=None)
    assert np.isfinite(np.asarray(y)).all()


def test_ssm_decode_matches_forward_stepwise(key):
    """Recurrent decode == chunked SSD on the same sequence, step by step."""
    from repro.models import ssm as ssm_mod

    cfg = tiny("ssm")
    p = ssm_mod.init_ssm_params(key, cfg, dtype=jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.3
    y_full = ssm_mod.ssm_forward(p, x, cfg)
    cache = {k: v[0] for k, v in
             ssm_mod.init_ssm_cache(cfg, b, 1, dtype=jnp.float32).items()}
    outs = []
    for t in range(s):
        y_t, cache = ssm_mod.ssm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


def test_vlm_image_conditioning_matters(key):
    cfg = tiny("vlm")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg)
    # gates init at 0 -> tanh(0)=0 -> cross blocks are identity at init;
    # open the gates to test conditioning
    params["cross_blocks"]["gate_attn"] = jnp.ones_like(
        params["cross_blocks"]["gate_attn"])
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    img1 = jax.random.normal(key, (2, 8, cfg.d_model), jnp.bfloat16)
    img2 = img1 * 3.0 + 1.0
    h1, _ = lm.forward_hidden(params, tokens, cfg, ctx, image_embeds=img1)
    h2, _ = lm.forward_hidden(params, tokens, cfg, ctx, image_embeds=img2)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-3

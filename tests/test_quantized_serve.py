"""ABQ serve-path tests: packing, accuracy ordering, memory compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.layers import QuantLinear
from repro.models.quantized import (
    QuantizeConfig,
    quantize_model,
    quantized_bytes,
)
from conftest import tiny


def _prefill_logits(params, cfg, key, img=None):
    ctx = ModelContext(cfg=cfg, remat=False)
    ts = (2, 32, cfg.n_codebooks) if cfg.family == "audio" else (2, 32)
    tokens = jax.random.randint(key, ts, 0, cfg.vocab_size)
    logits, cache = lm.prefill(params, tokens, cfg, ctx, max_len=40,
                               image_embeds=img)
    return tokens, logits, cache


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "audio"])
def test_w8a8_close_to_fp(family, key):
    cfg = tiny(family)
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=8, a_bits=8,
                                                    bit_balance=False))
    _, lo_fp, _ = _prefill_logits(params, cfg, key)
    _, lo_q, _ = _prefill_logits(qp, cfg, key)
    rel = float(jnp.linalg.norm((lo_q - lo_fp).astype(jnp.float32))
                / jnp.linalg.norm(lo_fp.astype(jnp.float32)))
    assert rel < 0.12, f"{family}: W8A8 deviates {rel:.3f} from fp"


def test_quant_error_orders_by_bits(key):
    """W8A8 error < W4A8 error < W2A8 error (paper Tables 6/7 ordering)."""
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    _, lo_fp, _ = _prefill_logits(params, cfg, key)
    errs = {}
    for bits in (8, 4, 2):
        qp = quantize_model(params, cfg, QuantizeConfig(
            w_bits=bits, a_bits=8, bit_balance=False))
        _, lo_q, _ = _prefill_logits(qp, cfg, key)
        errs[bits] = float(jnp.linalg.norm(
            (lo_q - lo_fp).astype(jnp.float32)))
    assert errs[8] < errs[4] < errs[2]


def test_bit_balance_beats_symmetric_w2(key):
    """Bit balance (paper §3.3): the symmetric 5-level grid {-2..2}
    reconstructs near-normal (symmetric) weights better than the 4-level
    symmetric INT2 grid the paper ablates against."""
    import numpy as np

    from repro.core import QuantSpec, dequantize_weight, quantize_weight, \
        weight_scales

    w = jnp.asarray(np.random.default_rng(0).normal(size=(512, 16)),
                    jnp.float32)
    def mse(spec):
        sc, zp = weight_scales(w, spec)
        q = quantize_weight(w, sc, zp, spec)
        return float(jnp.mean(jnp.square(
            dequantize_weight(q, sc, zp, spec) - w)))

    mse_sym = mse(QuantSpec(bits=2, symmetric=True))       # {-1, 0, 1}
    mse_bb = mse(QuantSpec(bits=2, bit_balance=True))      # {-2..2}
    assert mse_bb < mse_sym * 0.8, (mse_bb, mse_sym)


def test_bit_balance_model_level_not_worse(key):
    """Model-level: W2* should not be materially worse than asymmetric W2
    (it usually wins; random tiny weights make the margin noisy)."""
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    _, lo_fp, _ = _prefill_logits(params, cfg, key)
    errs = {}
    for bb in (False, True):
        qp = quantize_model(params, cfg, QuantizeConfig(
            w_bits=2, a_bits=8, bit_balance=bb))
        _, lo_q, _ = _prefill_logits(qp, cfg, key)
        errs[bb] = float(jnp.linalg.norm((lo_q - lo_fp).astype(jnp.float32)))
    assert errs[True] < errs[False] * 1.15


def test_memory_compression_ratios(key):
    """Packed W2 weights ~1/8 the bf16 block bytes (paper's 2.7-4.8x e2e)."""
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    fp_bytes = quantized_bytes(params["blocks"])
    q2 = quantize_model(params, cfg, QuantizeConfig(w_bits=2, a_bits=8,
                                                    bit_balance=False,
                                                    quantize_lm_head=False))
    q8 = quantize_model(params, cfg, QuantizeConfig(w_bits=8, a_bits=8,
                                                    quantize_lm_head=False))
    w2_bytes = quantized_bytes(q2["blocks"])
    w8_bytes = quantized_bytes(q8["blocks"])
    assert w2_bytes < fp_bytes / 4  # 2/16 packed + scales overhead
    assert w8_bytes < fp_bytes      # 8/16 + scales
    assert w2_bytes < w8_bytes / 2.5


def test_quantized_tree_structure(key):
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=2, a_bits=8))
    assert isinstance(qp["blocks"]["attn"]["wq"], QuantLinear)
    assert isinstance(qp["lm_head"], QuantLinear)
    # norms/embed stay fp
    assert qp["blocks"]["attn_norm"].dtype == jnp.bfloat16
    assert qp["embed"].dtype == jnp.bfloat16
    # stacked packing: leading layer dim preserved
    assert qp["blocks"]["attn"]["wq"].pw.planes.shape[0] == cfg.n_layers


def test_moe_expert_quantization_divisibility(key):
    """Experts quantize when ff % (32*tp) == 0, else fall back to bf16."""
    cfg = tiny("moe")  # moe_d_ff=64: 64 % 32 == 0 -> packable at tp=1
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=2, a_bits=8,
                                                    tensor_par=1))
    assert isinstance(qp["blocks"]["moe"]["w_gate"], QuantLinear)
    qp16 = quantize_model(params, cfg, QuantizeConfig(w_bits=2, a_bits=8,
                                                      tensor_par=16))
    # 64 % (32*16) != 0 -> dense fallback
    assert not isinstance(qp16["blocks"]["moe"]["w_gate"], QuantLinear)


def test_quantized_decode_runs(key):
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=2, a_bits=8,
                                                    bit_balance=True))
    ctx = ModelContext(cfg=cfg, remat=False)
    tokens, logits, cache = _prefill_logits(qp, cfg, key)
    nt = jnp.argmax(logits, -1).astype(jnp.int32)
    lo2, cache = lm.decode_step(qp, cache, nt, cfg, ctx)
    assert np.isfinite(np.asarray(lo2, np.float32)).all()

"""Chunked-prefill attention kernel (prefix-clamped flash over int8 KV).

The kernel runs in interpret mode (body executes on CPU) and is checked
five ways:

  * **bitwise** parity with the XLA mirror (`ops.chunk_attention`
    mode="xla") at equal tiling — same blocked int8 online-softmax math,
    same op sequence, so equal block_s must give equal bits (contiguous
    AND paged; start edges 0 / mid-block / block-aligned / full; GQA
    1/4/8). The prefix-bucketed XLA form is bitwise-equal to the
    unbucketed one (skipped blocks are select-discarded no-ops).
  * close agreement with the "naive" full-S dequantize-and-mask baseline
    and the f32 flash oracle (different quantization regime: loose tol).
  * block skip: S-blocks wholly past the chunk frontier ``start + C`` are
    never touched — NaN poison planted there must not propagate (it
    provably does propagate through the naive path, which reads-then-masks
    the whole row); same proof for unmapped pool blocks in paged mode.
  * tuning: `best_chunk_attn_block` legality, caching, and the
    page-divisor restriction.
  * engine level: `Engine(prefill_chunk=..., kv_block_size=...)` — the
    composition this kernel unlocks — decodes a long prompt bitwise-equal
    to the chunked slot-row engine, and its token streams match the
    unpaged one-shot-prefill engine.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels import tuning
from repro.kernels.chunk_attn import (
    chunk_attention_paged_pallas,
    chunk_attention_pallas,
)
from repro.kernels.ops import chunk_attention
from repro.models.attention import quantize_kv_cached


def _case(rng, b, s, c, h, kvh, d):
    q = jnp.asarray(rng.normal(size=(b, c, h, d)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    kq, ks, vq, vs = quantize_kv_cached(k, v)
    return q, k, v, kq, ks, vq, vs


def _paged_case(rng, kq, ks, vq, vs, page):
    """Chop a contiguous cache into a shuffled block pool + tables.
    Physical row 0 is TRASH (NaN-scale poisoned, like the real pool's
    never-attended row)."""
    b, kvh, s, d = kq.shape
    nb = s // page
    n_phys = b * nb + 1
    perm = rng.permutation(b * nb) + 1
    bt = jnp.asarray(perm.reshape(b, nb), jnp.int32)

    def pool_of(cache):
        if cache.ndim == 4:
            pool = np.zeros((n_phys, kvh, page, d), cache.dtype)
        else:
            pool = np.full((n_phys, kvh, page), np.nan, np.float32)
        for bi in range(b):
            for lb in range(nb):
                pool[perm[bi * nb + lb]] = np.asarray(
                    cache[bi, :, lb * page:(lb + 1) * page])
        return jnp.asarray(pool)

    return pool_of(kq), pool_of(ks), pool_of(vq), pool_of(vs), bt


# ---------------------------------------------------------------------------
# bitwise parity at equal tiling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (8, 1)])  # GQA 1/4/8
@pytest.mark.parametrize("start", [0, 13, 32, 120])  # edges: 0 / mid / aligned / full
def test_pallas_bitwise_vs_xla_at_equal_tiling(rng, h, kvh, start):
    b, s, c, d = 2, 128, 8, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    o_pal = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                            mode="pallas", interpret=True, block_s=32)
    o_xla = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                            mode="xla", block_s=32)
    np.testing.assert_array_equal(np.asarray(o_pal), np.asarray(o_xla))


@pytest.mark.parametrize("start", [0, 13, 64, 120])
def test_paged_bitwise_vs_contiguous_and_xla(rng, start):
    b, s, c, h, kvh, d, page = 2, 128, 8, 8, 2, 32, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    kp, ksp, vp, vsp, bt = _paged_case(rng, kq, ks, vq, vs, page)
    o_ct = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                           mode="pallas", interpret=True, block_s=16)
    o_pg = chunk_attention(q, kp, vp, ksp, vsp, block_tables=bt,
                           start=jnp.int32(start), mode="pallas",
                           interpret=True, block_s=16)
    o_px = chunk_attention(q, kp, vp, ksp, vsp, block_tables=bt,
                           start=jnp.int32(start), mode="xla", block_s=16)
    np.testing.assert_array_equal(np.asarray(o_ct), np.asarray(o_pg))
    np.testing.assert_array_equal(np.asarray(o_ct), np.asarray(o_px))


def test_xla_prefix_bucket_is_exact(rng):
    """Bucketing slices HBM work, never values: the bucketed XLA path is
    bitwise-equal to the full-S one at the same block_s (tail blocks are
    select-discarded no-ops either way)."""
    b, s, c, h, kvh, d = 1, 128, 8, 4, 2, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    for start, bucket in [(0, 32), (13, 32), (40, 64), (56, 64)]:
        o_full = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                                 mode="xla", block_s=32)
        o_bkt = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                                mode="xla", block_s=32,
                                prefix_bucket=bucket)
        np.testing.assert_array_equal(np.asarray(o_full), np.asarray(o_bkt))


def test_tuned_block_matches_pinned(rng):
    """Default (autotuned) block_s changes tiling, not numerics."""
    b, s, c, h, kvh, d = 1, 128, 8, 4, 2, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    o_auto = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(40),
                             mode="pallas", interpret=True)
    o_pin = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(40),
                            mode="pallas", interpret=True, block_s=64)
    np.testing.assert_allclose(np.asarray(o_auto), np.asarray(o_pin),
                               rtol=2e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# math: causal-within-chunk vs naive baseline and f32 oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("start", [0, 13, 56])
def test_matches_naive_and_oracle(rng, start):
    """Same attention, different quantization regime (int8 QK/PV BMMs vs
    f32 dequant): loose tolerance vs the naive mode; start=0 additionally
    checks the pure causal self-attention case against the f32 oracle."""
    b, s, c, h, kvh, d = 1, 64, 8, 4, 2, 32
    q, k, v, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    o_pal = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                            mode="pallas", interpret=True, block_s=32)
    o_nv = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(start),
                           mode="naive")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_nv),
                               rtol=5e-2, atol=1e-2)
    if start == 0:
        o_ref = R.flash_attention_ref(q, k[:, :c], v[:, :c], causal=True)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                   rtol=5e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# block skip (the perf claim, proven by poison)
# ---------------------------------------------------------------------------


def test_tail_blocks_past_frontier_never_touched(rng):
    """NaN poison planted past ``start + C`` must not reach the output:
    tail S-blocks are skipped (clamped index map + pl.when), not
    read-then-masked. The naive path *does* read the tail — the same
    poison provably NaNs it, so a silent no-op mask can't fake this."""
    b, s, c, bs = 1, 256, 8, 64
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, 8, 4, 64)
    start = 40  # frontier = 48, mid-block: blocks 1..3 must be untouched
    o_clean = chunk_attention_pallas(q, kq, vq, ks, vs,
                                     start=jnp.int32(start), scale=0.125,
                                     block_s=bs, interpret=True)
    ks_p = ks.at[:, :, 64:].set(np.nan)
    vs_p = vs.at[:, :, 64:].set(np.nan)
    kq_p = kq.at[:, :, 64:].set(127)
    vq_p = vq.at[:, :, 64:].set(127)
    o_poison = chunk_attention_pallas(q, kq_p, vq_p, ks_p, vs_p,
                                      start=jnp.int32(start), scale=0.125,
                                      block_s=bs, interpret=True)
    assert np.all(np.isfinite(np.asarray(o_poison)))
    np.testing.assert_array_equal(np.asarray(o_clean), np.asarray(o_poison))
    # potency check: the same poison NaNs the read-then-mask naive path
    o_nv = chunk_attention(q, kq_p, vq_p, ks_p, vs_p, start=jnp.int32(start),
                           mode="naive")
    assert np.any(np.isnan(np.asarray(o_nv)))


def test_paged_unmapped_blocks_never_touched(rng):
    """Pool blocks past the frontier (incl. TRASH, NaN-scaled by the
    fixture) are never streamed: poisoning every block the chunk does not
    own leaves the paged kernel's output unchanged."""
    b, s, c, h, kvh, d, page = 1, 128, 8, 4, 2, 32, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, c, h, kvh, d)
    kp, ksp, vp, vsp, bt = _paged_case(rng, kq, ks, vq, vs, page)
    start = 24  # frontier 32 = exactly one page: pages 1..3 untouched
    o_clean = chunk_attention_paged_pallas(
        q, kp, vp, ksp, vsp, bt, start=jnp.int32(start),
        scale=float(d) ** -0.5, block_s=page, interpret=True)
    mapped = set(np.asarray(bt[0, :1]).tolist())
    ksp_p, vsp_p = np.array(ksp), np.array(vsp)
    for phys in range(kp.shape[0]):
        if phys not in mapped:
            ksp_p[phys] = np.nan
            vsp_p[phys] = np.nan
    o_poison = chunk_attention_paged_pallas(
        q, kp, vp, jnp.asarray(ksp_p), jnp.asarray(vsp_p), bt,
        start=jnp.int32(start), scale=float(d) ** -0.5, block_s=page,
        interpret=True)
    assert np.all(np.isfinite(np.asarray(o_poison)))
    np.testing.assert_array_equal(np.asarray(o_clean), np.asarray(o_poison))


# ---------------------------------------------------------------------------
# dispatch / validation
# ---------------------------------------------------------------------------


def test_mode_env_validation(rng, monkeypatch):
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 4, 2, 32)
    monkeypatch.setenv("REPRO_CHUNK_ATTN", "bogus")
    with pytest.raises(ValueError, match="REPRO_CHUNK_ATTN"):
        chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(0))


def test_int8_cache_without_scales_raises(rng):
    q = jnp.asarray(rng.normal(size=(1, 4, 4, 32)).astype(np.float32))
    kq = jnp.zeros((1, 2, 64, 32), jnp.int8)
    vq = jnp.zeros((1, 2, 64, 32), jnp.int8)
    with pytest.raises(ValueError, match="k_scale"):
        chunk_attention(q, kq, vq, None, None, start=jnp.int32(0))


def test_pallas_mode_falls_back_to_xla_off_tpu(rng, monkeypatch):
    """REPRO_CHUNK_ATTN=pallas without a TPU (and without interpret) must
    produce the XLA mirror's exact output — same math, same tiling."""
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 4, 2, 32)
    monkeypatch.setenv("REPRO_CHUNK_ATTN", "pallas")
    o_env = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(8))
    monkeypatch.setenv("REPRO_CHUNK_ATTN", "xla")
    o_xla = chunk_attention(q, kq, vq, ks, vs, start=jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(o_env), np.asarray(o_xla))


def test_block_s_must_divide_s(rng):
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 4, 4, 32)
    with pytest.raises(ValueError, match="block_s"):
        chunk_attention_pallas(q, kq, vq, ks, vs, start=jnp.int32(0),
                               scale=1.0, block_s=48, interpret=True)


def test_attend_chunk_reaches_kernel(rng, key, monkeypatch):
    """Serving wiring: attend_chunk with backend='pallas' (interpret) runs
    the prefix-clamped kernel — start threads through as the frontier
    clamp — and matches the XLA-backend chunk step."""
    from repro.configs import ArchConfig
    from repro.models import attention as attn_mod

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    params = attn_mod.init_attn_params(key, cfg, dtype=jnp.float32)
    cache = {
        "k": jnp.asarray(rng.integers(-80, 80, size=(1, 2, 64, 16)),
                         jnp.int8),
        "k_scale": jnp.abs(jnp.asarray(
            rng.normal(size=(1, 2, 64)).astype(np.float32))) * 0.01,
        "v": jnp.asarray(rng.integers(-80, 80, size=(1, 2, 64, 16)),
                         jnp.int8),
        "v_scale": jnp.abs(jnp.asarray(
            rng.normal(size=(1, 2, 64)).astype(np.float32))) * 0.01,
    }
    x = jnp.asarray(rng.normal(size=(1, 4, 64)).astype(np.float32)) * 0.1
    start = jnp.asarray(17, jnp.int32)
    monkeypatch.setenv("REPRO_CHUNK_ATTN", "pallas")
    o_pal, c_pal = attn_mod.attend_chunk(params, x, cache, start, cfg,
                                         backend="pallas", interpret=True)
    monkeypatch.setenv("REPRO_CHUNK_ATTN", "xla")
    o_xla, c_xla = attn_mod.attend_chunk(params, x, cache, start, cfg,
                                         backend="xla")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_xla),
                               rtol=5e-2, atol=5e-2)
    for leaf in c_pal:  # the chunk's KV write is backend-independent
        np.testing.assert_array_equal(np.asarray(c_pal[leaf]),
                                      np.asarray(c_xla[leaf]))


# ---------------------------------------------------------------------------
# tuning shape class
# ---------------------------------------------------------------------------


def test_best_chunk_attn_block_is_kernel_legal_and_cached():
    a = tuning.best_chunk_attn_block(1, 8, 4, 128, 2048, 128)
    b = tuning.best_chunk_attn_block(1, 8, 4, 128, 2048, 128)
    assert a is b  # lru_cache hit
    assert 2048 % a.block_s == 0
    assert a.vmem_bytes <= tuning.VMEM_BYTES // 4


def test_best_chunk_attn_block_page_divisor_restriction():
    c = tuning.best_chunk_attn_block(1, 8, 4, 64, 2048, 128, page=256)
    assert 256 % c.block_s == 0  # paged legality: tile within one page
    # measure hook overrides the modeled ranking (auto_tune parity)
    seen = []
    m = tuning.best_chunk_attn_block(
        1, 8, 4, 64, 1024, 64,
        measure=lambda bs: seen.append(bs) or float(bs))
    assert m.block_s == min(seen)  # fastest-by-measure wins
    assert len(seen) > 1


def test_chunk_attn_cost_scales_with_prefix_not_s():
    """Fetched bytes follow the chunk frontier, not max_len — the roofline
    form of the kernel's whole point."""
    kw = dict(block_s=128)
    near = tuning.chunk_attn_cost(1, 32, 1, 128, 4096, 128, start=0, **kw)
    far = tuning.chunk_attn_cost(1, 32, 1, 128, 4096, 128, start=3968, **kw)
    assert near["cache_bytes"] < far["cache_bytes"]
    # and the short-prefix cost is independent of the cache capacity
    small_s = tuning.chunk_attn_cost(1, 32, 1, 128, 1024, 128, start=0, **kw)
    assert near["cache_bytes"] == small_s["cache_bytes"]


# ---------------------------------------------------------------------------
# engine level: the chunked+paged composition
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from conftest import tiny
    from repro.models import lm
    from repro.models.blocks import ModelContext
    from repro.models.quantized import QuantizeConfig, quantize_model

    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    return cfg, ctx, qp


def test_engine_chunked_paged_matches_one_shot_unpaged(served):
    """The composition the kernel unlocks: a long prompt through
    Engine(prefill_chunk=..., kv_block_size=...) decodes bitwise-equal to
    the chunked slot-row engine (same math, table indirection only) and
    its token streams match the unpaged one-shot-prefill engine."""
    from repro.serving import Engine, Request

    cfg, ctx, qp = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (13, 3, 21)]

    def run(**kw):
        eng = Engine(qp, cfg, ctx, n_slots=2, max_len=64, prefill_bucket=4,
                     **kw)
        sts = [eng.submit(Request(prompt=tuple(p), max_new_tokens=5))
               for p in prompts]
        eng.run()
        return [s.output() for s in sts], eng

    o_cp, eng_cp = run(prefill_chunk=3, kv_block_size=8)
    o_chunk, _ = run(prefill_chunk=3)
    o_shot, _ = run()
    assert o_cp == o_chunk  # paging is invisible to the chunked math
    assert o_cp == o_shot  # and the streams match one-shot prefill
    assert eng_cp.stats["prefill_chunks"] > 0  # long prompts went chunked
    assert eng_cp.pool.used_blocks == 0  # free-on-retire drained the pool


def test_engine_chunked_paged_interleaves_under_block_pressure(served):
    """A chunk-prefilling row must keep its neighbors decoding AND stay
    within its block reservation: tight pool, long prompt, short runner."""
    from repro.serving import Engine, Request

    cfg, ctx, qp = served
    rng = np.random.default_rng(3)
    runner_p = rng.integers(0, cfg.vocab_size, size=3).tolist()
    long_p = rng.integers(0, cfg.vocab_size, size=17).tolist()
    eng = Engine(qp, cfg, ctx, n_slots=2, max_len=64, prefill_bucket=4,
                 prefill_chunk=4, kv_block_size=8)
    runner = eng.submit(Request(prompt=tuple(runner_p), max_new_tokens=10))
    eng.step()
    long_st = eng.submit(Request(prompt=tuple(long_p), max_new_tokens=4))
    tokens_before = None
    while long_st.status in ("queued", "prefilling"):
        eng.step()
        if long_st.status == "prefilling" and tokens_before is None:
            tokens_before = len(runner.tokens)
    assert len(runner.tokens) > (tokens_before or 0)  # no stall
    eng.run()
    assert len(long_st.output()) == 4
    assert eng.stats["prefill_chunks"] == 5  # ceil(17 / 4)
    # solo oracle: interleaving never leaks into the chunked row's stream
    solo = Engine(qp, cfg, ctx, n_slots=2, max_len=64, prefill_bucket=4,
                  prefill_chunk=4, kv_block_size=8)
    ref = solo.submit(Request(prompt=tuple(long_p), max_new_tokens=4))
    solo.run()
    assert long_st.output() == ref.output()

"""Dry-run / roofline tooling tests (parsers + planning; no 512-device
compiles here — those are the dryrun deliverable itself)."""

import importlib
import json
import os
import sys

# make the top-level benchmarks/ package importable regardless of how
# pytest was invoked
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _dryrun():
    # import without triggering the 512-device XLA flag side-effect twice
    import repro.launch.dryrun as d

    return d


def test_collective_bytes_parser():
    d = _dryrun()
    hlo = """
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups=...
  %ar.1 = (s32[1024]{0}, f32[256,2]{1,0}) all-reduce(%a, %b), to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(%c), dimensions={0}
  %agd = bf16[64,128]{1,0} all-gather-done(%ag)
  %cp = u32[16]{0} collective-permute(%d), source_target_pairs=...
  %dot = f32[8,8]{1,0} dot(%x, %y)
"""
    out = d.collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 2  # -done not double counted
    assert out["all-reduce"] == 1024 * 4 + 256 * 2 * 4  # variadic tuple
    assert out["reduce-scatter"] == 32 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["all-to-all"] == 0


def test_tpu_artifact_bytes_classes():
    d = _dryrun()
    big = 64 * 1024 * 1024  # elements -> definitely over threshold
    hlo = f"""
  %cv = s32[{big}]{{0}} convert(s8[{big}]{{0}} %cache)
  %cv2 = f32[128]{{0}} convert(s8[128]{{0}} %small)
  %cat = s8[{big}]{{0}} concatenate(%a, %b), dimensions={{0}}
  %fus = s32[{big}]{{0}} fusion(%c), kind=kLoop
  %real = f32[{big}]{{0}} add(%x, %y)
"""
    art = d.tpu_artifact_bytes(hlo)
    assert art == big * 4 + big * 1 + big * 4  # convert + s8 concat + s32 fusion
    # decode mode additionally discounts big s8 fusions
    hlo2 = f"%f = s8[{big}]{{0}} fusion(%c), kind=kLoop"
    assert d.tpu_artifact_bytes(hlo2) == 0
    assert d.tpu_artifact_bytes(hlo2, decode=True) == big


def test_probe_plan_depths():
    d = _dryrun()
    from repro.configs import get_config

    for arch, unit, g_real in (("qwen3-4b", "layer", 36),
                               ("zamba2-7b", "group", 13),
                               ("llama-3.2-vision-90b", "group", 20)):
        plan = d.probe_plan(get_config(arch))
        assert plan["unit"] == unit
        assert plan["g_real"] == g_real
        assert plan["layers"][1] > plan["layers"][0]


def test_probe_extrapolation_exact():
    from benchmarks.roofline import _probe_total

    pr = {"gs": [2, 4], "g_real": 36, "batch_probe": 16, "batch_real": 256}
    # cost = 10 + 3*g at probe batch; g=36 -> 118; batch scale 16x -> 1888
    assert _probe_total(pr, [16.0, 22.0]) == (10 + 3 * 36) * 16


def test_cell_runnability_matrix():
    from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config

    runnable = 0
    for arch in ARCH_NAMES:
        if arch == "llama-7b":
            continue
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            if ok:
                runnable += 1
            else:
                assert shape.name == "long_500k"
                assert not cfg.supports_long_context
    assert runnable == 32  # 10 archs x 4 shapes - 8 long_500k skips


def test_serve_rules_are_tp_only():
    d = _dryrun()
    import jax
    from repro.configs import SHAPES

    # AbstractMesh: production topology without needing 256 real devices
    # (this test runs inside the single-device pytest process)
    mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    r_train = d.rules_for(SHAPES["train_4k"], mesh)
    r_dec = d.rules_for(SHAPES["decode_32k"], mesh)
    assert r_train.fsdp is not None
    assert r_dec.fsdp is None  # §Perf iteration 4
    r_long = d.rules_for(SHAPES["long_500k"], mesh)
    assert r_long.batch is None  # B=1 cannot shard over data


def test_auto_tune_prefers_small_bm_for_gemv():
    """Auto Kernel Search (paper Appendix D, TPU form): the decode GEMV
    (M=1) should pick the smallest M block (no padding waste) and a packed
    W2 config should model ~4x faster than W8 at the same shape."""
    from repro.kernels.tuning import auto_tune, model_cost

    best = auto_tune(1, 4096, 4096, w_bits=2)
    assert best.block_m == 8  # smallest tile: GEMV wastes no M padding
    assert best.vmem_bytes <= 32 * 2**20
    t2 = auto_tune(1, 4096, 4096, w_bits=2).t_us
    t8 = auto_tune(1, 4096, 4096, w_bits=8).t_us
    assert 3.0 < t8 / t2 < 5.0  # packed-bytes ratio, memory-bound

    # a measure callable overrides the model (real-TPU hook)
    best_measured = auto_tune(1, 4096, 4096, w_bits=2,
                              measure=lambda bm, bn, bk: float(bm + bn + bk))
    assert (best_measured.block_m, best_measured.block_n,
            best_measured.block_k) == (8, 128, 128)

"""Robustness-layer tests: deadlines, cancellation, failure isolation,
and the seeded fault-injection chaos property.

The load-bearing claims: (1) every failure is per-request — a timed-out,
cancelled, or logit-poisoned request retires alone (slot and pool blocks
freed like any retirement) while every other request's token stream stays
bitwise equal to a fault-free run; (2) the NaN guard rides the step's
existing single device→host transfer (no extra transfers, sentinel in the
token block); (3) a stuck engine raises `EngineStuck` with an actionable
diagnostic instead of a bare error or a hang; (4) under seeded random
fault schedules (injected pool exhaustion, NaN logits, clock jumps,
submit storms, cancels) the engine preserves pool block conservation
after every step and terminates every request in a terminal state — the
chaos property `run_chaos` also gates in ``run.py --check``.
"""

import contextlib
import signal

import numpy as np
import pytest

import jax

from conftest import tiny
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model
from repro.serving import (CANCELLED, FAILED, TIMED_OUT, Engine,
                           EngineStuck, FakeClock, FaultSchedule, Request,
                           SamplingParams, run_chaos)
from repro.serving.request import TERMINAL_STATUSES


@pytest.fixture(scope="module")
def served():
    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    return cfg, ctx, qp


def _engine(served, **kw):
    cfg, ctx, qp = served
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_bucket", 4)
    return Engine(qp, cfg, ctx, **kw)


def _prompts(cfg, rng, n, lo=3, hi=12):
    return [rng.integers(0, cfg.vocab_size, size=int(s)).tolist()
            for s in rng.integers(lo, hi, size=n)]


def _solo_output(served, request, **eng_kw):
    """The fault-free oracle: the request run alone on a fresh engine."""
    eng = _engine(served, **eng_kw)
    st = eng.submit(Request(prompt=request.prompt,
                            max_new_tokens=request.max_new_tokens,
                            eos_id=request.eos_id,
                            sampling=request.sampling))
    eng.run()
    return st.output()


@contextlib.contextmanager
def hard_timeout(seconds: int):
    """SIGALRM hard stop: a hung engine must fail the test, not wedge the
    suite (no pytest-timeout plugin in the container)."""
    def fire(signum, frame):
        raise TimeoutError(f"test exceeded {seconds}s hard timeout")

    old = signal.signal(signal.SIGALRM, fire)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# ---------------------------------------------------------------------------
# submit-time request validation
# ---------------------------------------------------------------------------


def test_request_validation_actionable_errors():
    with pytest.raises(ValueError, match="empty prompt"):
        Request(prompt=())
    with pytest.raises(ValueError, match="negative token id"):
        Request(prompt=(3, -1, 5))
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(prompt=(1,), max_new_tokens=0)
    for bad in (float("nan"), float("inf"), 0.0, -2.5):
        with pytest.raises(ValueError, match="deadline_s"):
            Request(prompt=(1,), deadline_s=bad)
        with pytest.raises(ValueError, match="ttft_deadline_s"):
            Request(prompt=(1,), ttft_deadline_s=bad)
    # valid deadlines coerce to float and survive
    r = Request(prompt=(1, 2), deadline_s=3, ttft_deadline_s=1)
    assert r.deadline_s == 3.0 and r.ttft_deadline_s == 1.0


# ---------------------------------------------------------------------------
# deadlines -> TIMED_OUT
# ---------------------------------------------------------------------------


def test_queued_request_times_out_without_admission(served):
    """A queued request past its deadline is expired by the sweep without
    ever taking a slot; deadline-less neighbors are untouched."""
    clk = FakeClock()
    eng = _engine(served, n_slots=1, clock=clk)
    keep = eng.submit(Request(prompt=(1, 2, 3, 4), max_new_tokens=6))
    doomed = eng.submit(Request(prompt=(5, 6, 7), max_new_tokens=6,
                                deadline_s=2.0))
    solo = _solo_output(served, keep.request, n_slots=1)
    while eng.has_work():
        eng.step()
        clk.advance(1.0)
    assert doomed.status == TIMED_OUT
    assert doomed.finish_reason == "timeout"
    assert doomed.tokens == []          # never admitted, nothing emitted
    assert keep.status in TERMINAL_STATUSES and keep.output() == solo
    assert eng.stats["timed_out"] == 1
    assert eng.metrics.counters["timed_out"] == 1
    assert eng.metrics.counters["finished"] == 1


def test_running_request_times_out_and_frees_capacity(served):
    """A running request expiring mid-decode retires TIMED_OUT between
    device steps, keeps the tokens it already streamed, and its freed slot
    admits queued work."""
    clk = FakeClock()
    eng = _engine(served, n_slots=1, clock=clk)
    doomed = eng.submit(Request(prompt=(1, 2, 3, 4), max_new_tokens=30,
                                deadline_s=3.5))
    waiting = eng.submit(Request(prompt=(9, 8, 7), max_new_tokens=4))
    solo = _solo_output(served, waiting.request, n_slots=1)
    while eng.has_work():
        eng.step()
        clk.advance(1.0)
    assert doomed.status == TIMED_OUT
    assert 0 < len(doomed.tokens) < 30   # partial stream survives
    assert waiting.output() == solo       # admitted into the freed slot
    snap = eng.metrics.snapshot()
    assert snap["terminal"]["timed_out"] == 1
    assert snap["terminal"]["finished"] == 1
    assert snap["terminal"]["in_flight"] == 0


def test_ttft_deadline_only_binds_before_first_token(served):
    """ttft_deadline_s expires a token-less request; once the first token
    streamed the same elapsed time is fine (only deadline_s binds)."""
    clk = FakeClock()
    eng = _engine(served, n_slots=1, clock=clk)
    # admitted immediately -> first token well inside the budget; the
    # request then runs long past ttft_deadline_s without expiring
    ok = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=5,
                            ttft_deadline_s=4.0))
    while eng.has_work():
        eng.step()
        clk.advance(1.0)
    assert ok.status in TERMINAL_STATUSES
    assert ok.finish_reason == "length"
    assert len(ok.tokens) == 5

    # stuck in the queue behind a long request -> expired by the sweep
    clk2 = FakeClock()
    eng2 = _engine(served, n_slots=1, clock=clk2)
    eng2.submit(Request(prompt=(1, 2, 3, 4), max_new_tokens=30))
    starved = eng2.submit(Request(prompt=(5, 6), max_new_tokens=4,
                                  ttft_deadline_s=3.0))
    while eng2.has_work():
        eng2.step()
        clk2.advance(1.0)
    assert starved.status == TIMED_OUT
    assert starved.first_token_t is None


def test_ttft_hopeless_admission_refusal(served):
    """Deadline-aware admission: queued work that cannot meet its TTFT
    budget at the recent step pace is expired instead of admitted —
    no prefill is wasted on a request whose client already gave up."""
    clk = FakeClock()
    eng = _engine(served, n_slots=2, clock=clk)
    hopeless = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=4,
                                  ttft_deadline_s=1.0))
    # a step pace far beyond the budget (normally learned from the EWMA
    # of real step wall time; pinned here for determinism)
    eng._step_ewma = 5.0
    eng.step()
    assert hopeless.status == TIMED_OUT
    assert eng.metrics.counters["admitted"] == 0


# ---------------------------------------------------------------------------
# cancellation -> CANCELLED at every lifecycle stage
# ---------------------------------------------------------------------------


def test_cancel_queued_running_and_unknown(served):
    clk = FakeClock()
    eng = _engine(served, n_slots=1, clock=clk)
    running = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=30))
    queued = eng.submit(Request(prompt=(4, 5), max_new_tokens=4))
    survivor = eng.submit(Request(prompt=(6, 7, 8), max_new_tokens=4))
    solo = _solo_output(served, survivor.request, n_slots=1)

    assert eng.cancel(queued.request_id)      # still QUEUED
    assert queued.status == CANCELLED and queued.tokens == []
    for _ in range(3):
        eng.step()
    assert eng.cancel(running.request_id)     # mid-decode, owns the slot
    assert running.status == CANCELLED
    assert 0 < len(running.tokens) < 30       # partial stream kept
    assert not eng.cancel(999)                # unknown id
    assert not eng.cancel(queued.request_id)  # already terminal
    eng.run()
    assert survivor.output() == solo          # unaffected, bitwise
    assert eng.stats["cancelled"] == 2
    snap = eng.metrics.snapshot()
    assert snap["terminal"] == {"finished": 1, "timed_out": 0,
                                "cancelled": 2, "failed": 0, "in_flight": 0}


def test_cancel_prefilling_request(served):
    """Cancel mid-chunked-prefill: the slot and its pool blocks free
    immediately (no first token ever streams)."""
    eng = _engine(served, n_slots=1, prefill_chunk=4, kv_block_size=8)
    long_prompt = tuple(range(1, 17))  # 16 tokens -> 4 chunks
    st = eng.submit(Request(prompt=long_prompt, max_new_tokens=4))
    eng.step()                      # admits, prefills the first chunk
    assert st.status == "prefilling"
    held = eng.pool.used_blocks
    assert held > 0
    assert eng.cancel(st.request_id)
    assert st.status == CANCELLED and st.tokens == []
    assert eng.pool.used_blocks == 0          # blocks reclaimed
    assert eng.pool.check() == []
    assert not eng.has_work()


def test_cancel_preempted_request(served):
    """A preempted (queued-for-resume) request cancels cleanly out of the
    scheduler heap."""
    eng = _engine(served, n_slots=2, prefill_bucket=4, kv_block_size=8,
                  kv_pool_tokens=48, overcommit=True)
    a = eng.submit(Request(prompt=tuple(range(1, 9)), max_new_tokens=20))
    b = eng.submit(Request(prompt=tuple(range(9, 17)), max_new_tokens=20))
    # drive until the scarce pool (6 blocks for two growing rows) forces
    # a preemption
    for _ in range(60):
        eng.step()
        if eng.stats["preemptions"]:
            break
    preempted = a if a.status == "preempted" else b
    assert preempted.status == "preempted"
    assert eng.cancel(preempted.request_id)
    assert preempted.status == CANCELLED
    assert len(eng.scheduler) == 0            # pulled from the heap
    eng.run()
    assert eng.pool.check() == []
    other = b if preempted is a else a
    assert other.status in TERMINAL_STATUSES


# ---------------------------------------------------------------------------
# failure isolation: NaN logits -> FAILED, batchmates bitwise-unchanged
# ---------------------------------------------------------------------------


def test_nan_poisoned_row_fails_alone_bitwise(served):
    """A NaN injected into one row's logits retires only that request as
    FAILED (offending step in the error payload); every other in-flight
    request finishes bitwise equal to the no-fault oracle, and the guard
    adds no device→host transfers (sentinel rides the token block)."""
    cfg, _, _ = served
    rng = np.random.default_rng(7)
    prompts = _prompts(cfg, rng, 3, lo=3, hi=6)
    eng = _engine(served, n_slots=3)
    states = [eng.submit(Request(prompt=tuple(p), max_new_tokens=8,
                                 sampling=SamplingParams(
                                     greedy=(i != 1), temperature=0.9,
                                     top_k=16, seed=i)))
              for i, p in enumerate(prompts)]
    solos = [_solo_output(served, st.request, n_slots=3) for st in states]

    for _ in range(3):
        eng.step()                 # all three rows running, some tokens out
    victim = states[0]
    assert victim.status == "running"
    eng.inject_nan(victim.slot)
    eng.run()

    assert victim.status == FAILED
    assert victim.finish_reason == "failed"
    err = victim.error
    assert err["kind"] == "non_finite_logits"
    assert err["step"] > 0 and err["tokens_streamed"] == len(victim.tokens)
    assert len(victim.tokens) < 8             # cut short by the fault
    for st, solo in zip(states[1:], solos[1:]):
        assert st.status not in (FAILED,)
        assert st.output() == solo            # bitwise: fault never leaked
    # the guard rides the existing single transfer per device step
    assert eng.stats["transfers"] == eng.stats["device_steps"]
    snap = eng.metrics.snapshot()
    assert snap["terminal"]["failed"] == 1
    assert snap["terminal"]["in_flight"] == 0


def test_poison_mask_disarms_after_one_step(served):
    """inject_nan is one-shot: after the poisoned step the same slot
    serves a fresh request normally."""
    eng = _engine(served, n_slots=1)
    first = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=6))
    eng.step()
    eng.inject_nan(0)
    eng.run()
    assert first.status == FAILED
    again = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=6))
    eng.run()
    assert again.finish_reason == "length"
    assert len(again.tokens) == 6
    with pytest.raises(ValueError, match="out of range"):
        eng.inject_nan(5)


# ---------------------------------------------------------------------------
# watchdog + stuck-engine diagnostics
# ---------------------------------------------------------------------------


def test_watchdog_counts_slow_steps(served):
    """Steps slower than watchdog_s are counted (engine never blocks);
    an injected clock jump is what a stall looks like to the watchdog."""
    clk = FakeClock()
    jumps = {"n": 0}

    def jump_twice(engine):
        if engine.stats["steps"] in (2, 4):
            clk.advance(9.0)
            jumps["n"] += 1

    eng = _engine(served, clock=clk, watchdog_s=1.0, fault_hook=jump_twice)
    eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=8))
    eng.run()
    assert jumps["n"] == 2
    assert eng.stats["slow_steps"] == 2
    assert eng.metrics.counters["watchdog_slow_steps"] == 2


def test_watchdog_env_default_and_validation(served, monkeypatch):
    monkeypatch.setenv("REPRO_WATCHDOG_S", "2.5")
    eng = _engine(served)
    assert eng.watchdog_s == 2.5
    monkeypatch.delenv("REPRO_WATCHDOG_S")
    assert _engine(served).watchdog_s is None
    with pytest.raises(ValueError, match="watchdog_s"):
        _engine(served, watchdog_s=0.0)


def test_engine_stuck_diagnostic_dump(served):
    """Exhausting max_steps raises EngineStuck whose message names the
    queue depth, per-slot request status, and terminal counters."""
    eng = _engine(served, n_slots=1)
    st = eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=30))
    eng.submit(Request(prompt=(4, 5), max_new_tokens=4))
    with pytest.raises(EngineStuck) as exc:
        eng.run(max_steps=3)
    msg = str(exc.value)
    assert "did not drain in 3 steps" in msg
    assert "queue: depth=1" in msg
    assert f"request {st.request_id} running" in msg
    assert "stats:" in msg and "timed_out=0" in msg
    # EngineStuck is a RuntimeError: existing callers' handlers still work
    assert isinstance(exc.value, RuntimeError)


def test_run_timeout_s_bounds_wall_time(served):
    clk = FakeClock()
    eng = _engine(served, clock=clk,
                  fault_hook=lambda e: clk.advance(1.0))
    eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=50))
    with pytest.raises(EngineStuck, match="timeout_s=2.5"):
        eng.run(timeout_s=2.5)


# ---------------------------------------------------------------------------
# injected pool exhaustion flows through real preemption
# ---------------------------------------------------------------------------


def test_injected_exhaust_preempts_and_recovers(served):
    """An injected PoolExhausted must drive the genuine preemption
    machinery (real victim, real resume-replay) — outputs stay bitwise
    equal to a fault-free run and the pool audit stays clean."""
    def run(fault_hook=None):
        eng = _engine(served, n_slots=2, kv_block_size=8,
                      overcommit=True, fault_hook=fault_hook)
        a = eng.submit(Request(prompt=tuple(range(1, 7)),
                               max_new_tokens=10))
        b = eng.submit(Request(prompt=tuple(range(7, 13)),
                               max_new_tokens=10))
        eng.run()
        assert eng.pool.check() == []
        return eng, [a.output(), b.output()]

    def exhaust_on_3(engine):
        if engine.stats["steps"] == 3:
            engine._fault_exhaust_once = True

    _, clean = run()
    eng, faulted = run(exhaust_on_3)
    assert eng.stats["preemptions"] >= 1      # the fault really evicted
    assert faulted == clean                   # replay resume is bitwise


# ---------------------------------------------------------------------------
# the chaos property
# ---------------------------------------------------------------------------


def test_chaos_schedule_preserves_invariants(served):
    """Seeded random fault schedule (exhaust + NaN + clock jumps + submit
    storms + cancels) over an overcommit chunked paged engine: pool block
    conservation holds after every step, every request (original and
    storm-injected) terminates, metrics conserve, and originals the
    faults never touched finish bitwise equal to their solo oracle."""
    cfg, _, _ = served
    with hard_timeout(300):
        rng = np.random.default_rng(11)
        clk = FakeClock()

        def factory(frng):
            n = int(frng.integers(3, 9))
            return Request(
                prompt=tuple(int(t) for t in
                             frng.integers(0, cfg.vocab_size, size=n)),
                max_new_tokens=int(frng.integers(2, 6)))

        schedule = FaultSchedule(
            seed=11, nan_rate=0.06, exhaust_rate=0.1, clock_rate=0.08,
            clock_jump_s=8.0, storm_rate=0.05, storm_size=3,
            cancel_rate=0.06, max_faults=12,
            request_factory=factory, clock=clk)
        eng = _engine(served, n_slots=4, prefill_chunk=4, kv_block_size=8,
                      kv_pool_tokens=128, overcommit=True, clock=clk,
                      fault_hook=schedule)
        requests = [Request(prompt=tuple(p),
                            max_new_tokens=int(g),
                            deadline_s=40.0 if i % 3 == 0 else None)
                    for i, (p, g) in enumerate(zip(
                        _prompts(cfg, rng, 8, lo=3, hi=10),
                        rng.integers(3, 8, size=8)))]
        result = run_chaos(eng, requests, schedule, max_steps=3000)
        assert result["violations"] == [], "\n".join(result["violations"])
        assert schedule.n_faults > 0          # the schedule actually fired
        assert eng.metrics.snapshot()["terminal"]["in_flight"] == 0

        # unaffected originals == FINISHED originals: every fault class
        # lands a different terminal status (nan->FAILED, cancel->
        # CANCELLED, clock-jump->TIMED_OUT), so FINISHED means untouched
        # — and untouched must be bitwise oracle-equal (preemption replay
        # and batch composition cannot change a stream).
        originals = result["states"][:len(requests)]
        finished = [st for st in originals if st.status == "finished"]
        assert finished, "chaos killed every original — weaken the rates"
        for st in finished:
            assert st.output() == _solo_output(
                served, st.request, n_slots=4, prefill_chunk=4,
                kv_block_size=8, kv_pool_tokens=128, overcommit=True)


def test_chaos_schedule_is_deterministic(served):
    """The same seed replays the same faults: audit logs and terminal
    statuses are identical across runs."""
    cfg, _, _ = served

    def run_once():
        clk = FakeClock()
        schedule = FaultSchedule(seed=5, nan_rate=0.1, exhaust_rate=0.15,
                                 cancel_rate=0.1, clock_rate=0.1,
                                 clock_jump_s=6.0, max_faults=8, clock=clk)
        eng = _engine(served, n_slots=3, kv_block_size=8,
                      kv_pool_tokens=96, overcommit=True, clock=clk,
                      fault_hook=schedule)
        rng = np.random.default_rng(6)
        reqs = [Request(prompt=tuple(p), max_new_tokens=5,
                        deadline_s=30.0)
                for p in _prompts(cfg, rng, 6, lo=3, hi=8)]
        result = run_chaos(eng, reqs, schedule, max_steps=2000)
        assert result["violations"] == []
        return (schedule.log,
                [st.status for st in result["states"]],
                [st.tokens for st in result["states"]])

    with hard_timeout(300):
        assert run_once() == run_once()


def test_fault_schedule_env_spec(served, monkeypatch):
    """REPRO_FAULTS installs a FaultSchedule on a plain engine; the run
    still satisfies all-terminal + conservation (no clock/storm faults
    are possible from the env — they need injected collaborators)."""
    monkeypatch.setenv("REPRO_FAULTS", "seed=2,nan=0.2,cancel=0.1")
    eng = _engine(served, n_slots=2)
    assert isinstance(eng.fault_hook, FaultSchedule)
    states = [eng.submit(Request(prompt=(1 + i, 2, 3), max_new_tokens=5))
              for i in range(4)]
    with hard_timeout(120):
        eng.run()
    assert all(st.status in TERMINAL_STATUSES for st in states)
    assert eng.metrics.snapshot()["terminal"]["in_flight"] == 0
    with pytest.raises(ValueError, match="REPRO_FAULTS"):
        FaultSchedule.from_spec("typo_rate=0.5")

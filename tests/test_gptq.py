"""GPTQ weight-only baseline (the paper's Table 6 comparator)."""

import jax.numpy as jnp
import numpy as np

from repro.core.gptq import gptq_pack_linear, gptq_quantize
from repro.core.quantizers import (
    QuantSpec,
    dequantize_weight,
    quantize_weight,
    weight_scales,
)


def _setup(rng, k=64, n=16, t=256, rank=8):
    basis = rng.normal(size=(rank, k))
    x = rng.normal(size=(t, rank)) @ basis + 0.1 * rng.normal(size=(t, k))
    w = rng.normal(size=(k, n)).astype(np.float32)
    return w, x


def test_gptq_beats_rtn_on_correlated_inputs(rng):
    w, x = _setup(rng)
    spec = QuantSpec(bits=3)
    lv, sc, zp = gptq_quantize(w, x, spec)
    w_gptq = (lv - zp) * sc
    sc2, zp2 = weight_scales(jnp.asarray(w), spec)
    w_rtn = np.asarray(dequantize_weight(
        quantize_weight(jnp.asarray(w), sc2, zp2, spec), sc2, zp2, spec))
    err_gptq = np.linalg.norm(x @ w_gptq - x @ w)
    err_rtn = np.linalg.norm(x @ w_rtn - x @ w)
    assert err_gptq < err_rtn * 0.8


def test_gptq_levels_in_range(rng):
    w, x = _setup(rng, k=32, n=8)
    for bits, bb in ((2, False), (2, True), (4, False)):
        spec = QuantSpec(bits=bits, bit_balance=bb)
        lv, _, _ = gptq_quantize(w, x, spec)
        assert lv.min() >= 0 and lv.max() <= spec.level_max


def test_gptq_pack_roundtrips_through_engine(rng):
    """GPTQ output serves through the same ABQ bit-plane kernel."""
    from repro.kernels import ref as R

    w, x = _setup(rng, k=64, n=16)
    pw = gptq_pack_linear(w, x, QuantSpec(bits=4))
    xq = jnp.asarray(np.clip(np.round(x[:4] * 10), -127, 127), jnp.int8)
    xs = jnp.ones((4, 1), jnp.float32) * 0.1
    y = R.abq_matmul_ref(xq, xs, pw.planes, pw.scale, pw.zero_point, 64,
                         out_dtype=jnp.float32)
    ref = (np.asarray(xq, np.float32) * 0.1) @ (
        (np.asarray(__import__("repro.core.bitplane",
                               fromlist=["unpack_levels"]).unpack_levels(
            pw.planes, 64)) - np.asarray(pw.zero_point))
        * np.asarray(pw.scale))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-4)

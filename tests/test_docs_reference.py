"""Docs-consistency gate: docs/REFERENCE.md cannot silently rot.

Every ``REPRO_*`` environment variable that appears in the source tree
(src/, benchmarks/, examples/) must be documented in docs/REFERENCE.md,
and every variable the docs claim exists must still appear in the code —
drift in either direction fails. A couple of structural anchors
(the serving surface and the --check failure names) are pinned the same
way so the reference tracks the code it describes.
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
REFERENCE = REPO / "docs" / "REFERENCE.md"
ARCHITECTURE = REPO / "docs" / "ARCHITECTURE.md"
_VAR = re.compile(r"REPRO_[A-Z0-9_]+")


def _source_vars() -> set:
    found = set()
    for sub in ("src", "benchmarks", "examples"):
        for py in (REPO / sub).rglob("*.py"):
            found |= set(_VAR.findall(py.read_text()))
    return found


def test_every_env_var_is_documented():
    ref = REFERENCE.read_text()
    documented = set(_VAR.findall(ref))
    in_code = _source_vars()
    missing = in_code - documented
    assert not missing, (
        f"REPRO_* vars read in the code but absent from docs/REFERENCE.md: "
        f"{sorted(missing)}")
    stale = documented - in_code
    assert not stale, (
        f"docs/REFERENCE.md documents vars no longer in the code: "
        f"{sorted(stale)}")


def test_reference_pins_serving_surface():
    ref = REFERENCE.read_text()
    for anchor in ("Server.generate", "Server.engine", "kv_block_size",
                   "kv_pool_tokens", "step_horizon", "prefill_chunk",
                   "top_p", "eos_id", "BENCH_serving.json",
                   # robustness surface: deadlines, cancellation,
                   # watchdog, fault injection, terminal conservation
                   "deadline_s", "ttft_deadline_s", "Engine.cancel",
                   "watchdog_s", "fault_hook", "FaultSchedule",
                   "EngineStuck", "terminal"):
        assert anchor in ref, f"REFERENCE.md lost its {anchor!r} section"


def test_reference_matches_check_failure_names():
    """The --check failure names documented must be the ones run.py can
    actually emit (string-level pin; run.py is import-cheap but the
    failure list is data in the source)."""
    ref = REFERENCE.read_text()
    run_src = (REPO / "benchmarks" / "run.py").read_text()
    names = set(re.findall(r'failures\.append\("([a-z_]+)"\)', run_src))
    assert names, "no failure names found in benchmarks/run.py"
    for name in names:
        assert name in ref, (
            f"run.py --check failure {name!r} is not documented in "
            "docs/REFERENCE.md")


def test_architecture_doc_exists_and_points_at_real_files():
    """Every `src/...` path ARCHITECTURE.md references must exist."""
    text = ARCHITECTURE.read_text()
    paths = set(re.findall(r"`(src/[\w/\.]+\.py)(?::\d+)?`", text))
    assert len(paths) >= 10, "ARCHITECTURE.md should map the source tree"
    for p in sorted(paths):
        assert (REPO / p).exists(), f"ARCHITECTURE.md references missing {p}"

"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED same-family config and runs one forward /
train step on CPU, asserting output shapes and no NaNs. The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation) — verified
here structurally through eval_shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import lm
from repro.models.blocks import ModelContext

_EXPECTED_FULL = {
    # (n_layers, d_model, vocab) sanity pins against the assignment table
    "zamba2-7b": (81, 3584, 32000),
    "grok-1-314b": (64, 6144, 131072),
    "qwen2-moe-a2.7b": (24, 2048, 151936),
    "qwen3-4b": (36, 2560, 151936),
    "gemma-7b": (28, 3072, 256000),
    "stablelm-12b": (40, 5120, 100352),
    "minitron-8b": (32, 4096, 256000),
    "mamba2-2.7b": (64, 2560, 50280),
    "llama-3.2-vision-90b": (100, 8192, 128256),
    "musicgen-large": (48, 2048, 2048),
    "llama-7b": (32, 4096, 32000),
}


@pytest.mark.parametrize("arch", list(_EXPECTED_FULL))
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    n_l, d, v = _EXPECTED_FULL[arch]
    assert cfg.n_layers == n_l
    assert cfg.d_model == d
    assert cfg.vocab_size == v
    cfg.validate()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_one_train_step(arch, key):
    cfg = get_smoke_config(arch)
    ctx = ModelContext(cfg=cfg, remat=True)
    params = lm.init_params(key, cfg)
    b, s = 2, 16
    ts = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    tokens = jax.random.randint(key, ts, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16) * 0.05

    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, cfg, ctx, n_loss_chunks=2)[0])(params)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), f"{arch}: NaN grad"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_shapes(arch, key):
    cfg = get_smoke_config(arch)
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg)
    b, s = 2, 16
    ts = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    tokens = jax.random.randint(key, ts, 0, cfg.vocab_size)
    img = (jax.random.normal(key, (b, cfg.n_image_tokens, cfg.d_model),
                             jnp.bfloat16)
           if cfg.family == "vlm" else None)
    h, _ = lm.forward_hidden(params, tokens, cfg, ctx, image_embeds=img)
    assert h.shape == (b, s, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch}: NaN hidden"

    logits, cache = lm.prefill(params, tokens, cfg, ctx, max_len=s + 4,
                               image_embeds=img)
    if cfg.family == "audio":
        assert logits.shape == (b, 1, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (b, 1, cfg.padded_vocab)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_param_count_via_eval_shape(arch, key):
    """FULL configs instantiate structurally (no allocation) and land in
    the right parameter-count ballpark."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected_min = {
        "zamba2-7b": 5e9, "grok-1-314b": 250e9, "qwen2-moe-a2.7b": 10e9,
        "qwen3-4b": 3e9, "gemma-7b": 7e9, "stablelm-12b": 10e9,
        "minitron-8b": 7e9, "mamba2-2.7b": 2e9,
        "llama-3.2-vision-90b": 80e9, "musicgen-large": 1.5e9,
        "llama-7b": 6e9,
    }[arch]
    assert n_params > expected_min, f"{arch}: {n_params:.2e} params"
    assert n_params < expected_min * 2.2, f"{arch}: {n_params:.2e} params"

"""Substrate tests: optimizer, checkpointing, losses, data."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.ckpt import checkpoint as ckpt
from repro.core.losses import akl_loss, dlc_loss


def test_adamw_minimizes_quadratic():
    cfg = optim.AdamWConfig(lr=0.1)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = optim.init(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["x"])))(params)
        params, state = optim.update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_adamw_per_leaf_lr_freezes():
    cfg = optim.AdamWConfig(lr=0.1)
    params = {"a": jnp.ones(()), "b": jnp.ones(())}
    state = optim.init(params, cfg)
    lr_tree = {"a": 0.1, "b": 0.0}  # b frozen
    grads = {"a": jnp.ones(()), "b": jnp.ones(())}
    params2, _ = optim.update(grads, state, params, cfg, lr_tree=lr_tree)
    assert float(params2["a"]) != 1.0
    assert float(params2["b"]) == 1.0


def test_adamw_bf16_moments():
    cfg = optim.AdamWConfig(lr=0.01, moment_dtype="bfloat16")
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    state = optim.init(params, cfg)
    assert state["m"]["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.ones((4,), jnp.bfloat16)}
    params2, state2 = optim.update(grads, state, params, cfg)
    assert params2["x"].dtype == jnp.bfloat16
    assert float(state2["m"]["x"][0]) != 0.0


def test_grad_clip():
    cfg = optim.AdamWConfig(lr=0.0, grad_clip_norm=1.0)
    g = {"x": jnp.full((4,), 100.0)}
    state = optim.init(g, cfg)
    # lr=0: params unchanged, but the update must not NaN with huge grads
    p2, _ = optim.update(g, state, {"x": jnp.zeros((4,))}, cfg)
    assert np.isfinite(np.asarray(p2["x"])).all()


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def test_dlc_loss_zero_at_match(rng):
    d = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    assert float(dlc_loss(d, d, d)) < 1e-5
    d2 = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    assert float(dlc_loss(d2, d, d)) > float(dlc_loss(d, d, d))


def test_akl_symmetric_and_zero_at_match(rng):
    logits = jnp.asarray(rng.normal(size=(2, 2, 8, 8)).astype(np.float32))
    p = jax.nn.softmax(logits, -1)
    q = jax.nn.softmax(logits * 0.5, -1)
    assert float(akl_loss(p, p)) < 1e-6
    np.testing.assert_allclose(float(akl_loss(p, q)), float(akl_loss(q, p)),
                               rtol=1e-5)
    assert float(akl_loss(p, q)) > 0


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(key):
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((8, 4)), "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path, key):
    tree = _tree(key)
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore_like(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity(tmp_path, key):
    """A stale .tmp dir (crash mid-save) must not be visible as a step."""
    tree = _tree(key)
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_2.tmp")  # simulated crash leftovers
    (tmp_path / "step_2.tmp" / "junk").write_text("partial")
    assert ckpt.latest_step(str(tmp_path)) == 1
    # a later complete save with the same step must clean up and win
    ckpt.save(str(tmp_path), 2, tree)
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_checkpoint_async_and_gc(tmp_path, key):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _tree(key)
    for step in (1, 2, 3, 4):
        saver.save(step, tree)
    saver.wait()
    steps = ckpt.all_steps(str(tmp_path))
    assert steps[-1] == 4 and len(steps) <= 3  # gc keeps the tail


def test_checkpoint_elastic_reshard(tmp_path, key):
    """Restore onto a different device layout (1 device here; shardings
    tree given) — exercises the device_put resharding path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree(key)
    ckpt.save(str(tmp_path), 3, tree)
    from repro.dist.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda a: NamedSharding(mesh, P(*((None,) * np.ndim(a)))), tree)
    restored = ckpt.restore_like(str(tmp_path), 3, tree, shardings=shardings)
    assert np.array_equal(np.asarray(tree["params"]["w"]),
                          np.asarray(restored["params"]["w"]))


def test_checkpoint_missing_leaf_raises(tmp_path, key):
    tree = _tree(key)
    ckpt.save(str(tmp_path), 1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ckpt.restore_like(str(tmp_path), 1, bigger)

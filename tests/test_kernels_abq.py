"""ABQ GEMM Pallas kernel vs the pure-jnp oracle: shape/dtype/bit sweeps.

Everything runs in interpret mode on CPU (the kernel body executes in
Python), asserting exact agreement for the integer pipeline (the math is
exact in int32) and allclose for the fp epilogue.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, act_scales, pack_weight, quantize_act
from repro.kernels import ref as R
from repro.kernels.abq_matmul import abq_matmul_pallas
from repro.kernels import ops as O


def _mk(rng, m, k, n, w_bits, bb, a_bits=8):
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    wspec = QuantSpec(bits=w_bits, bit_balance=bb)
    pw = pack_weight(w, wspec)
    aspec = QuantSpec(bits=a_bits, symmetric=True, granularity="per_token")
    xs = act_scales(x, aspec)
    xq = quantize_act(x, xs, aspec)
    return xq, xs, pw, w


@pytest.mark.parametrize("w_bits,bb", [(1, False), (2, False), (2, True),
                                       (3, False), (4, False), (8, False)])
def test_abq_kernel_bit_sweep(rng, w_bits, bb):
    xq, xs, pw, _ = _mk(rng, 32, 256, 128, w_bits, bb)
    y_ref = R.abq_matmul_ref(xq, xs, pw.planes, pw.scale, pw.zero_point, 256,
                             out_dtype=jnp.float32)
    y_pal = abq_matmul_pallas(xq, xs, pw.planes, pw.scale, pw.zero_point,
                              block_m=32, block_n=128, block_k=128,
                              out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (1, 128, 128, 8, 128, 128),     # decode GEMV shape
    (7, 96, 256, 16, 128, 32),      # M padding + small K blocks
    (64, 512, 128, 32, 128, 256),   # multi-step K accumulation
    (100, 224, 384, 64, 128, 224),  # K not multiple of block... clamps
])
def test_abq_kernel_shape_sweep(rng, m, k, n, bm, bn, bk):
    xq, xs, pw, _ = _mk(rng, m, k, n, 2, True)
    kp = pw.planes.shape[1] * 32
    xq_p = jnp.pad(xq, ((0, 0), (0, kp - k)))
    y_ref = R.abq_matmul_ref(xq_p, xs, pw.planes, pw.scale, pw.zero_point, kp,
                             out_dtype=jnp.float32)
    bk = min(bk, kp)
    while kp % bk:
        bk -= 32
    y_pal = abq_matmul_pallas(xq_p, xs, pw.planes, pw.scale, pw.zero_point,
                              block_m=bm, block_n=bn, block_k=bk,
                              out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_abq_kernel_dtype_sweep(rng, out_dtype):
    xq, xs, pw, _ = _mk(rng, 16, 128, 128, 4, False)
    y_ref = R.abq_matmul_ref(xq, xs, pw.planes, pw.scale, pw.zero_point, 128,
                             out_dtype=out_dtype)
    y_pal = abq_matmul_pallas(xq, xs, pw.planes, pw.scale, pw.zero_point,
                              block_m=16, block_n=128, block_k=128,
                              out_dtype=out_dtype, interpret=True)
    assert y_pal.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2 if out_dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2)


def test_abq_matches_exact_integer_dequant(rng):
    """End-to-end identity: ABQ output == dequant(W) @ dequant(X) exactly."""
    from repro.core import dequantize_weight, weight_scales, quantize_weight

    xq, xs, pw, w = _mk(rng, 24, 160, 128, 3, False)
    spec = QuantSpec(bits=3)
    sc, zp = weight_scales(w, spec)
    q = quantize_weight(w, sc, zp, spec)
    w_deq = dequantize_weight(q, sc, zp, spec)
    y_exact = (xq.astype(jnp.float32) * xs) @ w_deq
    kp = pw.planes.shape[1] * 32
    y_abq = R.abq_matmul_ref(jnp.pad(xq, ((0, 0), (0, kp - 160))), xs,
                             pw.planes, pw.scale, pw.zero_point, kp,
                             out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_abq), np.asarray(y_exact),
                               rtol=1e-5, atol=1e-4)


def test_ops_wrapper_backend_equivalence(rng):
    """ops.abq_matmul xla path == pallas path == ref."""
    xq, xs, pw, _ = _mk(rng, 10, 96, 128, 2, True)
    y_xla = O.abq_matmul(xq, xs, pw, backend="xla", out_dtype=jnp.float32)
    y_pal = O.abq_matmul(xq, xs, pw, backend="pallas", interpret=True,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal),
                               rtol=1e-6, atol=1e-5)


def test_abq_linear_quant_error_small_at_w8a8(rng):
    w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    pw = pack_weight(w, QuantSpec(bits=8))
    y = O.abq_linear(x, pw, act_bits=8, backend="xla", out_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 2e-2


def test_grouped_ref_matches_per_channel_when_uniform(rng):
    """g128 with a single group == per-channel on that group."""
    xq, xs, pw, w = _mk(rng, 8, 128, 128, 4, False)
    y_pc = R.abq_matmul_ref(xq, xs, pw.planes, pw.scale, pw.zero_point, 128,
                            out_dtype=jnp.float32)
    spec_g = QuantSpec(bits=4, granularity="per_group", group_size=128)
    pw_g = pack_weight(w, spec_g)
    y_g = R.abq_matmul_grouped_ref(
        xq, xs, pw_g.planes, pw_g.scale, pw_g.zero_point, 128, 128,
        out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_pc),
                               rtol=1e-5, atol=1e-4)

"""Pallas decode-attention kernel (flash-decoding over the int8 cache).

The kernel runs in interpret mode (body executes in Python on CPU) and is
checked four ways:

  * parity with the jnp "int8" path — same int8-BMM regime, so the only
    divergence is per-block (vs per-row) prob re-quantization: tight
    tolerance, plus a looser check against the f32 oracle;
  * ``length`` edge cases: 0 (defined as a zero output), mid-block, full S;
  * GQA ratios 1/4/8 (the G query rows of a KV head share one MXU tile);
  * block-skip: S-blocks wholly past ``length`` are never touched — NaN
    poison planted in the tail scales must NOT propagate (it provably does
    propagate through the jnp path, which reads-then-masks the tail).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels import tuning
from repro.kernels.decode_attn import decode_attention_pallas
from repro.kernels.ops import decode_attention
from repro.models.attention import quantize_kv_cached


def _case(rng, b, s, h, kvh, d):
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    kq, ks, vq, vs = quantize_kv_cached(k, v)
    return q, k, v, kq, ks, vq, vs


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (8, 1)])  # GQA 1/4/8
def test_pallas_parity_vs_jnp_int8(rng, h, kvh):
    b, s, d = 2, 128, 64
    q, k, v, kq, ks, vq, vs = _case(rng, b, s, h, kvh, d)
    lens = jnp.asarray([s, s // 2], jnp.int32)
    o_jnp = decode_attention(q, kq, vq, ks, vs, length=lens,
                             fused_dequant="int8")
    o_pal = decode_attention(q, kq, vq, ks, vs, length=lens,
                             fused_dequant="pallas", interpret=True,
                             block_s=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_jnp),
                               rtol=2e-2, atol=5e-3)
    # and against the f32 oracle within the int8-attention budget
    o_ref = R.flash_attention_ref(q, k, v, causal=True, q_offset=s - 1)
    np.testing.assert_allclose(np.asarray(o_pal[:1]), np.asarray(o_ref[:1]),
                               rtol=5e-2, atol=1e-2)


def test_pallas_tuned_block_matches_pinned(rng):
    """Default (autotuned) block_s changes tiling, not numerics."""
    b, s, h, kvh, d = 1, 128, 4, 2, 32
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, h, kvh, d)
    lens = jnp.asarray([100], jnp.int32)
    o_auto = decode_attention(q, kq, vq, ks, vs, length=lens,
                              fused_dequant="pallas", interpret=True)
    o_pin = decode_attention(q, kq, vq, ks, vs, length=lens,
                             fused_dequant="pallas", interpret=True,
                             block_s=64)
    np.testing.assert_allclose(np.asarray(o_auto), np.asarray(o_pin),
                               rtol=2e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# length edge cases
# ---------------------------------------------------------------------------


def test_length_zero_is_zero_output(rng):
    """Attention over an empty prefix: the kernel's pinned convention is a
    zero row (the jnp paths degenerate to a uniform average instead)."""
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 4, 32)
    o = decode_attention_pallas(q, kq, vq, ks, vs, scale=1.0,
                                length=jnp.zeros((1,), jnp.int32),
                                block_s=32, interpret=True)
    assert np.all(np.asarray(o) == 0.0)


@pytest.mark.parametrize("length", [1, 40, 64])  # first pos, mid-block, full
def test_length_edges_match_jnp(rng, length):
    b, s = 1, 64
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, 4, 2, 32)
    lens = jnp.full((b,), length, jnp.int32)
    o_jnp = decode_attention(q, kq, vq, ks, vs, length=lens,
                             fused_dequant="int8")
    o_pal = decode_attention(q, kq, vq, ks, vs, length=lens,
                             fused_dequant="pallas", interpret=True,
                             block_s=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_jnp),
                               rtol=2e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# block skip
# ---------------------------------------------------------------------------


def test_masked_tail_blocks_never_touched(rng):
    """NaN poison planted past ``length`` must not reach the output: tail
    S-blocks are skipped (clamped index map + pl.when), not read-then-masked.
    The jnp int8 path *does* read the tail — the same poison provably NaNs
    it, so a silent no-op mask cannot fake this test out."""
    b, s, bs = 2, 256, 64
    q, _, _, kq, ks, vq, vs = _case(rng, b, s, 8, 4, 64)
    lens = jnp.asarray([40, 200], jnp.int32)  # tails start mid-block
    o_clean = decode_attention_pallas(q, kq, vq, ks, vs, scale=0.125,
                                      length=lens, block_s=bs,
                                      interpret=True)
    ks_p = ks.at[0, :, 40:].set(np.nan).at[1, :, 200:].set(np.nan)
    vs_p = vs.at[0, :, 40:].set(np.nan).at[1, :, 200:].set(np.nan)
    kq_p = kq.at[0, :, 40:].set(127).at[1, :, 200:].set(127)
    vq_p = vq.at[0, :, 40:].set(127).at[1, :, 200:].set(127)
    o_poison = decode_attention_pallas(q, kq_p, vq_p, ks_p, vs_p, scale=0.125,
                                       length=lens, block_s=bs,
                                       interpret=True)
    assert np.all(np.isfinite(np.asarray(o_poison)))
    np.testing.assert_array_equal(np.asarray(o_clean), np.asarray(o_poison))
    # potency check: the same poison NaNs the read-then-mask jnp path
    o_jnp = decode_attention(q, kq_p, vq_p, ks_p, vs_p, length=lens,
                             fused_dequant="int8")
    assert np.any(np.isnan(np.asarray(o_jnp)))


# ---------------------------------------------------------------------------
# dispatch / validation
# ---------------------------------------------------------------------------


def test_pallas_mode_falls_back_to_int8_off_tpu(rng, monkeypatch):
    """REPRO_DECODE_ATTN=pallas without a TPU (and without interpret) must
    produce the jnp int8 path's exact output — same math, XLA-lowered."""
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 2, 32)
    lens = jnp.asarray([64], jnp.int32)
    monkeypatch.setenv("REPRO_DECODE_ATTN", "pallas")
    o_env = decode_attention(q, kq, vq, ks, vs, length=lens)
    monkeypatch.setenv("REPRO_DECODE_ATTN", "int8")
    o_int8 = decode_attention(q, kq, vq, ks, vs, length=lens)
    np.testing.assert_array_equal(np.asarray(o_env), np.asarray(o_int8))


def test_int8_cache_without_scales_raises(rng):
    q = jnp.asarray(rng.normal(size=(1, 1, 4, 32)).astype(np.float32))
    kq = jnp.zeros((1, 2, 64, 32), jnp.int8)
    vq = jnp.zeros((1, 2, 64, 32), jnp.int8)
    ks = jnp.ones((1, 2, 64), jnp.float32)
    with pytest.raises(ValueError, match="k_scale"):
        decode_attention(q, kq, vq, None, None)
    with pytest.raises(ValueError, match="v_scale"):
        decode_attention(q, kq, vq, ks, None)


def test_block_s_must_divide_s(rng):
    q, _, _, kq, ks, vq, vs = _case(rng, 1, 64, 4, 4, 32)
    with pytest.raises(ValueError, match="block_s"):
        decode_attention_pallas(q, kq, vq, ks, vs, scale=1.0, block_s=48,
                                interpret=True)


def test_attend_decode_reaches_pallas_kernel(rng, key, monkeypatch):
    """Serving wiring: attend_decode with backend='pallas' (interpret) runs
    the flash-decoding kernel — pos threads through as the block-skip
    length — and matches the XLA-backend decode step."""
    from repro.configs import ArchConfig
    from repro.models import attention as attn_mod

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    params = attn_mod.init_attn_params(key, cfg, dtype=jnp.float32)
    cache = {
        "k": jnp.asarray(rng.integers(-80, 80, size=(2, 2, 64, 16)),
                         jnp.int8),
        "k_scale": jnp.abs(jnp.asarray(
            rng.normal(size=(2, 2, 64)).astype(np.float32))) * 0.01,
        "v": jnp.asarray(rng.integers(-80, 80, size=(2, 2, 64, 16)),
                         jnp.int8),
        "v_scale": jnp.abs(jnp.asarray(
            rng.normal(size=(2, 2, 64)).astype(np.float32))) * 0.01,
    }
    x = jnp.asarray(rng.normal(size=(2, 1, 64)).astype(np.float32)) * 0.1
    pos = jnp.asarray(17, jnp.int32)
    monkeypatch.setenv("REPRO_DECODE_ATTN", "pallas")
    o_pal, _ = attn_mod.attend_decode(params, x, cache, pos, cfg,
                                      backend="pallas", interpret=True)
    monkeypatch.setenv("REPRO_DECODE_ATTN", "int8")
    o_xla, _ = attn_mod.attend_decode(params, x, cache, pos, cfg,
                                      backend="xla")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_xla),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# tuning shape class
# ---------------------------------------------------------------------------


def test_best_decode_attn_block_is_kernel_legal_and_cached():
    a = tuning.best_decode_attn_block(4, 8, 4, 2048, 128)
    b = tuning.best_decode_attn_block(4, 8, 4, 2048, 128)
    assert a is b  # lru_cache hit
    assert 2048 % a.block_s == 0
    assert a.vmem_bytes <= tuning.VMEM_BYTES // 4


def test_best_decode_attn_block_prefers_sub_s_tiles_at_long_s():
    """Long caches must get a sub-S tile — block_s == S would make the
    length-aware skip a no-op (every step fetches the whole cache)."""
    for s in (512, 2048, 4096):
        c = tuning.best_decode_attn_block(4, 32, 1, s, 128)
        assert c.block_s < s, (s, c)
    # tiny caches collapse to one block
    assert tuning.best_decode_attn_block(2, 4, 2, 64, 64).block_s == 64


def test_decode_attn_cost_charges_block_granularity():
    """Fetched bytes round valid_len up to whole blocks (tail waste)."""
    r_small = tuning.decode_attn_cost(1, 1, 1, 1024, 128, block_s=128,
                                      valid_len=130)
    r_big = tuning.decode_attn_cost(1, 1, 1, 1024, 128, block_s=1024,
                                    valid_len=130)
    assert r_small["cache_bytes"] < r_big["cache_bytes"]


# ---------------------------------------------------------------------------
# sampling decode (satellite: PRNG key through the generate scan)
# ---------------------------------------------------------------------------


def test_generate_tokens_topk1_equals_greedy(key):
    from conftest import tiny
    from repro.models import lm
    from repro.models.blocks import ModelContext
    from repro.models.quantized import QuantizeConfig, quantize_model

    cfg = tiny("dense")
    ctx = ModelContext(cfg=cfg, remat=False)
    params = lm.init_params(key, cfg)
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)

    logits, cache = lm.prefill(qp, tokens, cfg, ctx, max_len=32)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    g_greedy, _ = lm.generate_tokens(qp, cache, first, 5, cfg, ctx)

    _, cache2 = lm.prefill(qp, tokens, cfg, ctx, max_len=32)
    g_topk1, _ = lm.generate_tokens(qp, cache2, first, 5, cfg, ctx,
                                    key=jax.random.PRNGKey(3), top_k=1)
    np.testing.assert_array_equal(np.asarray(g_greedy), np.asarray(g_topk1))


def test_sample_logits_masks_padding_vocab(key):
    """Padding-head columns (untrained rows of a padded_vocab-wide head)
    must get zero probability — even when their logits are the largest."""
    from repro.models.lm import sample_logits

    logits = jnp.full((4, 1, 256), -1.0, jnp.float32)
    logits = logits.at[..., 200:].set(50.0)  # poison the padding columns
    for i in range(8):
        t = sample_logits(logits, jax.random.fold_in(key, i),
                          temperature=1.0, vocab_size=200)
        assert np.all(np.asarray(t) < 200)


def test_server_sampling_reproducible_and_in_vocab():
    from repro.launch.serve import Server

    server = Server(arch="qwen3-4b", smoke=True, w_bits=4, max_len=64)
    kw = dict(max_new_tokens=5, greedy=False, temperature=0.8, top_k=8)
    o1, _ = server.generate([[1, 2, 3], [4, 5]], seed=7, **kw)
    o2, _ = server.generate([[1, 2, 3], [4, 5]], seed=7, **kw)
    o3, _ = server.generate([[1, 2, 3], [4, 5]], seed=8, **kw)
    assert o1 == o2  # pinned seed reproduces
    assert o1 != o3  # fresh seed explores
    # strictly in the REAL vocab: padding-head columns must be masked out
    # of the sampling distribution (they are untrained rows)
    assert all(0 <= t < server.cfg.vocab_size for o in o1 + o3 for t in o)

"""ABQ calibration mechanics (the paper's PTQ loop, CPU-sized)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import (
    CalibConfig,
    block_apply_fq,
    calibrate_block,
    calibrate_model,
    init_block_qstate,
    lr_tree_for,
    smoothquant_s_init,
    stack_qstates,
)
from repro.models import lm
from repro.models.blocks import ModelContext
from conftest import tiny


def test_qstate_structure_uniform_across_blocks(key):
    """Edge and middle blocks must produce identical qstate STRUCTURE so
    per-block states stack (compensation frozen, not absent, mid-stack)."""
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    st_edge = init_block_qstate(bp, edge_block=True)
    st_mid = init_block_qstate(bp, edge_block=False)
    assert jax.tree.structure(st_edge) == jax.tree.structure(st_mid)
    assert "comp_a" in st_edge["mlp"]["w_down"]
    stacked = stack_qstates([st_edge, st_mid])
    assert stacked["mlp"]["w_down"]["comp_a"].shape[0] == 2


def test_lr_tree_freezes_compensation_mid_stack(key):
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    st = init_block_qstate(bp, edge_block=False)
    ccfg = CalibConfig()
    lrs = lr_tree_for(st, ccfg, edge_block=False)
    assert lrs["mlp"]["w_down"]["comp_a"] == 0.0
    assert lrs["mlp"]["w_down"]["log_s"] == ccfg.lr_balance
    assert lrs["attn"]["wq"]["alpha_raw"] == ccfg.lr_clip
    lrs_e = lr_tree_for(st, ccfg, edge_block=True)
    assert lrs_e["mlp"]["w_down"]["comp_a"] == ccfg.lr_clip


def test_fq_block_matches_fp_at_high_bits(key):
    """W8A8 fake-quant block output ~= fp block output."""
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    ccfg = CalibConfig(w_bits=8, a_bits=8)
    st = init_block_qstate(bp, edge_block=False)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32) * 0.3
    y_q, attn_q = block_apply_fq(bp, st, x, cfg, ccfg, quant=True)
    y_fp, attn_fp = block_apply_fq(bp, None, x, cfg, ccfg, quant=False)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05
    assert attn_q.shape == attn_fp.shape


def test_calibrate_block_reduces_loss(key):
    cfg = tiny("dense")
    params = lm.init_params(key, cfg)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    ccfg = CalibConfig(w_bits=3, a_bits=8, epochs=8)
    x = jax.random.normal(key, (2, 2, 16, cfg.d_model), jnp.float32) * 0.3

    from repro.core.losses import dlc_loss

    def eval_loss(qs):
        y_q, _ = block_apply_fq(bp, qs, x[0], cfg, ccfg, quant=True)
        y_fp, _ = block_apply_fq(bp, None, x[0], cfg, ccfg, quant=False)
        return float(dlc_loss(y_q.astype(jnp.float32),
                              y_fp.astype(jnp.float32),
                              y_fp.astype(jnp.float32)))

    st0 = init_block_qstate(bp, edge_block=True)
    before = eval_loss(st0)
    st, _, _ = calibrate_block(bp, x, x, cfg, ccfg, edge_block=True)
    after = eval_loss(st)
    assert after < before, f"calibration did not reduce DLC: {before}->{after}"


def test_calibrate_model_end_to_end_mechanics(key):
    cfg = tiny("ssm")  # attention-free branch: DLC only (AKL inapplicable)
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 1, 16), 0, cfg.vocab_size)
    states = calibrate_model(params, toks, cfg,
                             CalibConfig(w_bits=4, a_bits=8, epochs=1))
    assert len(states) == cfg.n_layers
    stacked = stack_qstates(states)
    assert stacked["ssm"]["wx"]["log_s"].shape == (cfg.n_layers, cfg.d_model)

    # packs into a servable tree
    from repro.models.quantized import QuantizeConfig, quantize_model

    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8,
                                                    bit_balance=False),
                        calib={"blocks": stacked})
    ctx = ModelContext(cfg=cfg, remat=False)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, _ = lm.prefill(qp, tokens, cfg, ctx, max_len=20)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_smoothquant_init_balances_scales():
    act_amax = jnp.asarray([10.0, 0.1, 1.0])
    w = jnp.asarray(np.random.default_rng(0).normal(size=(3, 4)), jnp.float32)
    s = smoothquant_s_init(act_amax, w)
    # outlier activation channel gets the largest weight-side multiplier
    assert float(s[0]) > float(s[2]) > float(s[1])

"""End-to-end behaviour test for the paper's system: train a tiny LM,
ABQ-quantize it (the paper's full deployment path), and serve it — the
quantized model must still model the planted structure of the data."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import ArchConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model


def test_train_quantize_serve_end_to_end():
    cfg = ArchConfig(name="e2e", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128)
    ctx = ModelContext(cfg=cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg, dtype=jnp.float32)
    opt_cfg = optim.AdamWConfig(lr=5e-3)
    opt = optim.init(params, opt_cfg)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64))

    @jax.jit
    def step(p, o, batch):
        loss, grads = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, batch, cfg, ctx, n_loss_chunks=2)[0])(p)
        p, o = optim.update(grads, o, p, opt_cfg)
        return p, o, loss

    losses = []
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in ds.batch(i, 8).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, f"no learning: {losses[0]}->{losses[-1]}"

    # quantize (W4A8 RTN) and check the served model still beats chance
    qp = quantize_model(params, cfg, QuantizeConfig(w_bits=4, a_bits=8))
    b = {k: jnp.asarray(v) for k, v in ds.batch(999, 8).items()}
    loss_fp, _ = lm.loss_fn(params, b, cfg, ctx, n_loss_chunks=2)
    loss_q, _ = lm.loss_fn(qp, b, cfg, ctx, n_loss_chunks=2)
    chance = np.log(cfg.vocab_size)
    assert float(loss_q) < chance - 0.2, "quantized model lost the structure"
    assert float(loss_q) < float(loss_fp) + 0.15, "W4A8 degraded too much"

    # serve a few tokens
    logits, cache = lm.prefill(qp, b["tokens"][:2, :32], cfg, ctx, max_len=40)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        logits, cache = lm.decode_step(qp, cache, tok, cfg, ctx)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

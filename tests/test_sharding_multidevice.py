"""Multi-device sharding tests (subprocess: 8 placeholder devices).

The production 256/512-chip meshes are exercised by the dry-run; here we
verify on 8 devices that (a) param specs are consistent, (b) the train step
runs SPMD with numerically-identical results to single-device, (c) the MoE
shard_map path equals the local path, (d) int8 gradient compression psum
converges.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.dist.sharding import ShardingRules
from repro.models import lm
from repro.models import moe as moe_mod
from repro.models.blocks import ModelContext
from repro.models.shardings import param_pspecs, batch_pspecs

out = {}
from repro.dist.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = ArchConfig(name="t", family="moe", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=2, d_ff=128, vocab_size=512, n_experts=4,
                 top_k=2, moe_d_ff=64).with_kv_replication(2)
rules = ShardingRules().resolve(mesh)
key = jax.random.PRNGKey(0)
params = lm.init_params(key, cfg, dtype=jnp.float32)
tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}

# ---- single device reference
ctx1 = ModelContext(cfg=cfg, mesh=None, remat=False)
loss1, _ = lm.loss_fn(params, batch, cfg, ctx1, n_loss_chunks=2)

# ---- SPMD
ctx8 = ModelContext(cfg=cfg, mesh=mesh, rules=rules, remat=False)
psp = param_pspecs(params, cfg, rules, mesh)
pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), psp,
                      is_leaf=lambda x: isinstance(x, P))
params_sh = jax.tree.map(lambda a, s: jax.device_put(a, s), params, pshard)
bspec = batch_pspecs(batch, rules, mesh)
batch_sh = jax.tree.map(
    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), batch, bspec)
with mesh:
    loss8, _ = jax.jit(
        lambda p, b: lm.loss_fn(p, b, cfg, ctx8, n_loss_chunks=2))(
        params_sh, batch_sh)
out["loss_single"] = float(loss1)
out["loss_spmd"] = float(loss8)

# ---- MoE shard_map vs local (ample capacity: no shard-local drops, so the
# two dispatch layouts must agree exactly; tight-capacity dropping behaviour
# is covered by test_models.test_moe_capacity_drop_is_graceful)
cfg_nodrop = dataclasses.replace(cfg, capacity_factor=4.0)
x = jax.random.normal(key, (8, 16, cfg.d_model), jnp.float32)
moe_p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
y_local, aux_local = moe_mod.moe_ffn(moe_p, x, cfg_nodrop, mesh=None)
with mesh:
    y_dist, aux_dist = jax.jit(lambda p, xx: moe_mod.moe_ffn(
        p, xx, cfg_nodrop, mesh=mesh, dp_axes=("data",), tp_axis="model"))(
        moe_p, x)
out["moe_max_diff"] = float(jnp.max(jnp.abs(y_local - y_dist)))
out["moe_aux_diff"] = abs(float(aux_local) - float(aux_dist))

# ---- compression: int8 EF psum == plain mean within quant error;
# error feedback drives the long-run average error to ~0
from repro.dist import compression
mesh_p = make_mesh((2, 4), ("pod", "data"))
g = {"w": jax.random.normal(key, (16,), jnp.float32)}
err = compression.init_error_state(g)
with mesh_p:
    fn = jax.jit(lambda gg, ee: compression.compressed_pmean(
        gg, ee, mesh_p, ("pod",)))
    total = jnp.zeros((16,))
    ee = err
    for i in range(20):
        mean_g, ee = fn(g, ee)
        total = total + mean_g["w"]
    # replicated grads: mean == g; EF keeps cumulative sums aligned
    out["comp_rel_err"] = float(
        jnp.linalg.norm(total / 20 - g["w"]) / jnp.linalg.norm(g["w"]))

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_spmd_loss_matches_single_device(results):
    assert abs(results["loss_spmd"] - results["loss_single"]) < 2e-2, results


def test_moe_shard_map_matches_local(results):
    assert results["moe_max_diff"] < 1e-4, results
    # aux is a per-shard routing statistic (top-1 counts), pmean'd — it is
    # close to, not identical to, the global statistic
    assert results["moe_aux_diff"] < 0.1, results


def test_compressed_psum_error_feedback(results):
    assert results["comp_rel_err"] < 0.02, results

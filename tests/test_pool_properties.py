"""Property tests for the paged-pool + scheduler invariants.

The preemption/overcommit engine rests on a handful of host-side safety
properties that no single example test can pin — they must hold across
*every* interleaving of reserve / ensure(alloc) / release(free) / preempt:

* conservation: ``sum(allocated) <= n_blocks`` and
  ``free + used == n_blocks`` after every operation;
* exclusivity: no physical block is ever mapped by two live slots;
* TRASH isolation: block 0 is never handed out, and every unmapped table
  entry points at it;
* ``pool.stats()`` counters conserve (watermarks bound current values,
  used equals the sum of per-slot holdings).

The suite drives `BlockPool` (both conservative and optimistic modes)
with random op sequences and checks the invariants after every single
op, and drives `Scheduler` with random submit/pop/requeue interleavings
to pin priority-FIFO order and requeue fairness.

When hypothesis is installed the sequences are generated (and shrunk)
under the ``ci`` profile registered in `test_properties.py` style; the
containers that lack it run the same drivers under a seeded fallback
fuzzer instead, so the invariants are exercised either way.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:  # the fallback fuzzer below still runs
    HAVE_HYPOTHESIS = False

from repro.serving.paged import TRASH, BlockPool, PoolExhausted
from repro.serving.request import PREEMPTED, Request, RequestState
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------------------
# BlockPool invariants under random op interleavings
# ---------------------------------------------------------------------------


def check_pool_invariants(pool: BlockPool) -> None:
    """Every safety property the engine relies on, checked structurally."""
    held = [pool.held(s) for s in range(pool.n_slots)]
    all_held = [b for hs in held for b in hs]
    # conservation: free + used == n_blocks, used == sum of holdings
    assert pool.free_blocks + pool.used_blocks == pool.n_blocks
    assert pool.used_blocks == len(all_held)
    assert len(all_held) <= pool.n_blocks
    # exclusivity: a block is held by at most one slot (and at most once)
    assert len(all_held) == len(set(all_held))
    # TRASH isolation: never allocated, never on the free list; ids valid
    assert TRASH not in all_held
    for b in all_held:
        assert 1 <= b <= pool.n_blocks
    # the table mirrors the holdings exactly: row s maps its held blocks
    # in logical order and TRASH everywhere else
    for s in range(pool.n_slots):
        row = pool.table[s]
        assert list(row[: len(held[s])]) == held[s]
        assert all(int(x) == TRASH for x in row[len(held[s]):])
    # stats counters conserve and watermarks bound the current values
    stats = pool.stats()
    assert stats["free_blocks"] == pool.free_blocks
    assert stats["used_blocks"] == pool.used_blocks
    assert stats["free_blocks"] + stats["used_blocks"] == stats["n_blocks"]
    assert stats["peak_used_blocks"] >= stats["used_blocks"]
    assert stats["min_free_blocks"] <= stats["free_blocks"]
    assert 0 <= stats["reserved_blocks"] <= stats["n_blocks"]
    assert stats["alloc_failures"] >= 0
    if not pool.optimistic:
        # conservative mode: allocation never outruns the reservation
        for s in range(pool.n_slots):
            assert len(held[s]) <= int(pool._reserved[s])


def drive_pool(ops, n_blocks: int, optimistic: bool) -> BlockPool:
    """Apply an op sequence, checking every invariant after every op.
    ``ops`` is a list of (op, slot, n) with op in reserve / ensure /
    release / preempt — preempt models the engine's eviction (release-all
    on a slot that may be mid-allocation)."""
    pool = BlockPool(n_blocks, 4, n_slots=4, max_blocks=8,
                     optimistic=optimistic)
    for op, slot, n in ops:
        before = (pool.free_blocks,
                  [tuple(pool.held(s)) for s in range(pool.n_slots)])
        try:
            if op == "reserve":
                pool.reserve(slot, n)
            elif op == "ensure":
                pool.ensure(slot, n)
            elif op in ("release", "preempt"):
                assert pool.release(slot) >= 0
        except PoolExhausted:
            assert optimistic  # only the optimistic path may raise it
            # exhaustion is atomic: the failed demand took nothing
            after = (pool.free_blocks,
                     [tuple(pool.held(s)) for s in range(pool.n_slots)])
            assert after == before
        except (RuntimeError, ValueError):
            pass  # refusals must leave state intact — checked below
        check_pool_invariants(pool)
    return pool


_OPS = ("reserve", "ensure", "release", "preempt")


def _random_ops(rng, size: int):
    return [(_OPS[int(rng.integers(0, 4))], int(rng.integers(0, 4)),
             int(rng.integers(1, 15))) for _ in range(size)]


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("optimistic", [False, True])
def test_pool_invariants_fuzz(seed, optimistic):
    """Seeded fallback fuzzer: same driver as the hypothesis property,
    runs in every container."""
    rng = np.random.default_rng(seed)
    drive_pool(_random_ops(rng, 40), n_blocks=int(rng.integers(1, 13)),
               optimistic=optimistic)


def test_trash_block_never_handed_out_exhaustively():
    """Drain the whole pool: every allocated id is 1..n_blocks, never 0."""
    pool = BlockPool(6, 2, n_slots=3, max_blocks=8, optimistic=True)
    pool.ensure(0, 3)
    pool.ensure(1, 3)
    handed = pool.held(0) + pool.held(1)
    assert sorted(handed) == [1, 2, 3, 4, 5, 6]
    assert TRASH not in handed
    with pytest.raises(PoolExhausted):
        pool.ensure(2, 1)
    check_pool_invariants(pool)


def test_release_returns_blocks_once():
    """Double release is a no-op, not a double-free: the second call
    reclaims zero blocks and conservation holds."""
    pool = BlockPool(4, 4, n_slots=2, max_blocks=8, optimistic=True)
    pool.ensure(0, 3)
    assert pool.release(0) == 3
    assert pool.release(0) == 0
    assert pool.free_blocks == 4
    check_pool_invariants(pool)


# ---------------------------------------------------------------------------
# Scheduler: priority-FIFO order survives requeue interleavings
# ---------------------------------------------------------------------------


def _state(rid: int, priority: int) -> RequestState:
    return RequestState(
        request=Request(prompt=(1, 2, 3), max_new_tokens=4,
                        priority=priority),
        request_id=rid, arrival_t=0.0, submit_t=0.0)


def drive_scheduler(prios, churn) -> None:
    """Submit N requests, pop some, requeue a churned subset (preserved
    ``queue_seq``), then drain: the drain order is exactly the global
    (priority, original-arrival) order — a preempted request is never
    demoted behind later arrivals — and nothing is lost or duplicated."""
    sched = Scheduler()
    states = [_state(i, p) for i, p in enumerate(prios)]
    for s in states:
        sched.submit(s)
    popped = sched.pop_admissions(len(states) // 2 + 1)
    kept = list(popped)
    for idx in churn:
        if kept:
            victim = kept.pop(idx % len(kept))
            victim.status = PREEMPTED
            sched.requeue(victim)
    drained = []
    while len(sched):
        drained.extend(sched.pop_admissions(3))
    # nothing lost, nothing duplicated
    assert sorted(s.request_id for s in drained + kept) == \
        sorted(s.request_id for s in states)
    # the post-churn drain comes out in global (priority, arrival) order
    order = [(s.request.priority, s.queue_seq) for s in drained]
    assert order == sorted(order)
    # every queue_seq was assigned exactly once and preserved
    assert len({s.queue_seq for s in states}) == len(states)


@pytest.mark.parametrize("seed", range(8))
def test_scheduler_requeue_preserves_priority_fifo_fuzz(seed):
    rng = np.random.default_rng(seed)
    prios = [int(p) for p in rng.integers(0, 3,
                                          size=int(rng.integers(1, 13)))]
    churn = [int(c) for c in rng.integers(0, 12,
                                          size=int(rng.integers(0, 9)))]
    drive_scheduler(prios, churn)


def test_requeued_head_beats_later_arrivals():
    """A requeued request re-enters ahead of every same-priority request
    that arrived after it."""
    sched = Scheduler()
    first = _state(0, 1)
    sched.submit(first)
    (head,) = sched.pop_admissions(1)
    assert head is first
    later = [_state(i + 1, p) for i, p in enumerate((0, 1, 1, 2))]
    for s in later:
        sched.submit(s)
    sched.requeue(first)
    drained = []
    while len(sched):
        drained.extend(sched.pop_admissions(1))
    same = [s for s in drained if s.request.priority == 1]
    assert same[0] is first  # ahead of both later priority-1 arrivals
    # but NOT ahead of better-priority traffic
    assert drained[0] is later[0]


# ---------------------------------------------------------------------------
# hypothesis-generated versions of the same drivers (ci profile)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    pool_ops = st.lists(
        st.tuples(st.sampled_from(_OPS), st.integers(0, 3),
                  st.integers(1, 14)),
        min_size=1, max_size=40)

    @given(ops=pool_ops, n_blocks=st.integers(1, 12),
           optimistic=st.booleans())
    def test_pool_invariants_property(ops, n_blocks, optimistic):
        drive_pool(ops, n_blocks, optimistic)

    @given(prios=st.lists(st.integers(0, 2), min_size=1, max_size=12),
           churn=st.lists(st.integers(0, 11), max_size=8))
    def test_scheduler_requeue_property(prios, churn):
        drive_scheduler(prios, churn)
else:
    def test_pool_invariants_property():
        pytest.skip("hypothesis not installed in this container "
                    "(the seeded fuzz tests above cover the driver)")

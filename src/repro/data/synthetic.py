"""Deterministic synthetic token pipeline.

A Zipf-distributed Markov-ish stream with enough learnable structure that a
~100M model's loss drops well below the unigram entropy in a few hundred
steps (the structure: each token biases the next token's bucket). Used by
the end-to-end training example, the calibration set, and the PPL benchmark.

Design mirrors a production pipeline: the dataset is an infinite, seekable
sequence of fixed-length samples; every sample is derivable from (seed, index)
alone, so resuming a crashed run at step N yields byte-identical batches —
checkpoint/restart changes nothing about the data order (fault-tolerance
requirement, DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    seed: int = 1234
    zipf_a: float = 1.2
    n_codebooks: int = 0  # audio archs: multi-stream tokens


class SyntheticLM:
    """Infinite deterministic LM dataset; sample(i) -> (tokens, labels)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        # static Zipf unigram over the vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._unigram = probs / probs.sum()
        # hidden structure: token t prefers the bucket hash(t) for its successor
        self._bucket_of = rng.integers(0, 64, size=v)
        self._bucket_tokens = [
            np.where(self._bucket_of == b)[0] for b in range(64)
        ]
        # make sure no bucket is empty
        for b in range(64):
            if len(self._bucket_tokens[b]) == 0:
                self._bucket_tokens[b] = np.array([b % v])

    def sample(self, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        n_stream = max(cfg.n_codebooks, 1)
        out = np.empty((cfg.seq_len, n_stream), np.int32)
        tok = rng.choice(cfg.vocab_size, size=n_stream, p=self._unigram)
        for t in range(cfg.seq_len):
            out[t] = tok
            nxt = []
            for s in range(n_stream):
                if rng.random() < 0.75:  # structured transition
                    cand = self._bucket_tokens[self._bucket_of[tok[s]]]
                    nxt.append(cand[rng.integers(len(cand))])
                else:
                    nxt.append(rng.choice(cfg.vocab_size, p=self._unigram))
            tok = np.array(nxt)
        return out if cfg.n_codebooks else out[:, 0]

    def batch(self, step: int, batch_size: int,
              host_id: int = 0, n_hosts: int = 1) -> dict:
        """Per-host slice of the global batch at ``step`` (data parallel I/O:
        each host materializes only its shard)."""
        assert batch_size % n_hosts == 0
        local = batch_size // n_hosts
        base = step * batch_size + host_id * local
        toks = np.stack([self.sample(base + i) for i in range(local)])
        labels = np.roll(toks, -1, axis=1)
        if toks.ndim == 3:
            labels[:, -1, :] = 0
        else:
            labels[:, -1] = 0
        return {"tokens": toks, "labels": labels}


def calibration_segments(vocab_size: int, n_segments: int, seq_len: int,
                         batch: int = 1, seed: int = 99,
                         n_codebooks: int = 0) -> np.ndarray:
    """The paper's calibration set: n random segments of seq_len tokens
    (they use 128 × 2048 from WikiText2; we draw from the synthetic dist)."""
    ds = SyntheticLM(DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                                seed=seed, n_codebooks=n_codebooks))
    segs = np.stack([
        np.stack([ds.sample(i * batch + j) for j in range(batch)])
        for i in range(n_segments)
    ])
    return segs

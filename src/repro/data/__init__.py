from repro.data.synthetic import DataConfig, SyntheticLM, calibration_segments

__all__ = ["DataConfig", "SyntheticLM", "calibration_segments"]

"""Bit-plane packing: the storage format of the ABQ arbitrary-bit engine.

The paper's BitPacking (§3.4, step 1) re-lays a q-bit quantized tensor from
``[M, K, q]`` bit-interleaved form to ``[q, M, K]`` plane-major form so every
1-bit matrix is contiguous for the Binary TensorCore. The TPU adaptation keeps
the same plane-major idea but packs 32 contraction-dim bits per ``uint32``
word — the natural vector-register width — giving HBM layout

    planes : uint32 [n_planes, K/32, N]

for a (K, N) weight. Plane ``s`` holds bit ``s`` of the *unsigned level
index*; a value is reconstructed as ``sum_s 2^s * plane_s`` and dequantized
with ``(q - zero_point) * scale``.

K is padded up to a multiple of 32 with zero bits (zero level index); because
the integer-GEMM identity subtracts ``zero_point * rowsum(x_q)`` computed over
the *unpadded* K, padding contributes exactly ``-zp * 0`` and is harmless as
long as the activation rows are zero-padded too (the kernels guarantee this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

WORD_BITS = 32


def padded_k(k: int) -> int:
    return (k + WORD_BITS - 1) // WORD_BITS * WORD_BITS


def pack_bitplanes(q: Array, n_planes: int) -> Array:
    """Pack unsigned level indices (K, N) int32 -> uint32 [n_planes, K/32, N].

    Pure jnp; runs once offline per weight so clarity beats speed here.
    """
    if q.ndim != 2:
        raise ValueError(f"expected 2-D level index, got shape {q.shape}")
    k, n = q.shape
    kp = padded_k(k)
    if kp != k:
        q = jnp.pad(q, ((0, kp - k), (0, 0)))
    q = q.astype(jnp.uint32)
    # bits: [n_planes, K, N]
    shifts = jnp.arange(n_planes, dtype=jnp.uint32)[:, None, None]
    bits = (q[None] >> shifts) & jnp.uint32(1)
    # pack 32 consecutive K positions into one word
    bits = bits.reshape(n_planes, kp // WORD_BITS, WORD_BITS, n)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))[
        None, None, :, None
    ]
    words = jnp.sum(bits * weights, axis=2, dtype=jnp.uint32)
    return words


def unpack_bitplanes(planes: Array, k: int, dtype=jnp.int8) -> Array:
    """uint32 [n_planes, K/32, N] -> binary [n_planes, K, N] in ``dtype``."""
    n_planes, kw, n = planes.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, None, :, None]
    bits = (planes[:, :, None, :] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(n_planes, kw * WORD_BITS, n)
    return bits[:, :k, :].astype(dtype)


def unpack_levels(planes: Array, k: int, dtype=jnp.int32) -> Array:
    """Reconstruct unsigned level indices (K, N) from planes."""
    n_planes = planes.shape[0]
    bits = unpack_bitplanes(planes, k, dtype=jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(n_planes, dtype=jnp.uint32))[
        :, None, None
    ]
    return jnp.sum(bits * weights, axis=0).astype(dtype)


def pack_act_rows(x_q: Array) -> Array:
    """Bit-pack an int8 activation matrix's *sign-magnitude planes*.

    Unused by the default weight-side-only decomposition but kept as the
    faithful two-sided variant (paper Eq. 8–10): returns uint32
    [p, M, K/32] planes of the unsigned (level-index) activation.
    """
    if x_q.dtype != jnp.int8:
        raise ValueError("expected int8 container")
    m, k = x_q.shape
    kp = padded_k(k)
    x = x_q.astype(jnp.int32)
    if kp != k:
        x = jnp.pad(x, ((0, 0), (0, kp - k)))
    # shift to unsigned levels: assumes symmetric container [-127,127] -> +127
    levels = (x + 127).astype(jnp.uint32)
    shifts = jnp.arange(8, dtype=jnp.uint32)[:, None, None]
    bits = (levels[None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(8, m, kp // WORD_BITS, WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))[
        None, None, None, :
    ]
    return jnp.sum(bits * weights, axis=3, dtype=jnp.uint32)

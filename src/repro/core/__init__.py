"""ABQ-LLM core: quantizers, bit-plane packing, calibration losses."""

from repro.core.bitplane import (
    pack_bitplanes,
    padded_k,
    unpack_bitplanes,
    unpack_levels,
)
from repro.core.losses import akl_loss, block_mse, dlc_loss
from repro.core.quantizers import (
    PackedWeight,
    QuantSpec,
    act_scales,
    dequantize_act,
    dequantize_weight,
    fake_quant_act,
    fake_quant_weight,
    pack_weight,
    quantize_act,
    quantize_weight,
    weight_scales,
)

__all__ = [
    "PackedWeight",
    "QuantSpec",
    "act_scales",
    "akl_loss",
    "block_mse",
    "dequantize_act",
    "dequantize_weight",
    "dlc_loss",
    "fake_quant_act",
    "fake_quant_weight",
    "pack_bitplanes",
    "pack_weight",
    "padded_k",
    "quantize_act",
    "quantize_weight",
    "unpack_bitplanes",
    "unpack_levels",
    "weight_scales",
]

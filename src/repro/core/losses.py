"""Calibration losses of ABQ-LLM: DLC (Eq. 2) and AKL (Eq. 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-8


def _cos(a: Array, b: Array, axis: int = -1) -> Array:
    num = jnp.sum(a * b, axis=axis)
    den = jnp.linalg.norm(a, axis=axis) * jnp.linalg.norm(b, axis=axis)
    return num / jnp.maximum(den, _EPS)


def dlc_loss(d_q: Array, d_fp: Array, d_fp_star: Array) -> Array:
    """Double Log-Cosine distribution-correction loss (Eq. 2).

    ``d_q``       quantized block output (quantized stream input),
    ``d_fp``      full-precision block output (clean fp input),
    ``d_fp_star`` fp block applied to the quantized stream's input.

    All are (batch, seq, d). Cosine is per token; the two log terms anchor the
    quantized output to both the clean and the drifted fp distribution. Cosines
    are clamped to (eps, 1] so the loss is finite and -> 0 at perfect match.
    """
    c1 = jnp.clip(_cos(d_q, d_fp), _EPS, 1.0)
    c2 = jnp.clip(_cos(d_q, d_fp_star), _EPS, 1.0)
    return jnp.mean(-jnp.log(c1) - jnp.log(c2))


def _kl(p: Array, q: Array, axis: int = -1) -> Array:
    p = jnp.clip(p, _EPS, 1.0)
    q = jnp.clip(q, _EPS, 1.0)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=axis)


def akl_loss(attn_q: Array, attn_fp: Array) -> Array:
    """Attention-aware symmetric KL divergence (Eq. 4).

    ``attn_*`` are attention probability maps (..., q_len, kv_len), rows
    summing to 1. Symmetric KL restores the first-token attention-sink
    pattern that quantization disrupts (paper Fig. 2).
    """
    kl_fwd = _kl(attn_q, attn_fp)
    kl_bwd = _kl(attn_fp, attn_q)
    return jnp.mean(kl_fwd + kl_bwd)


def block_mse(d_q: Array, d_fp: Array) -> Array:
    """OmniQuant-style plain block-reconstruction MSE (ablation baseline)."""
    return jnp.mean(jnp.square(d_q - d_fp))

"""Uniform affine quantizers for ABQ-LLM.

Implements the paper's quantization grid conventions (§3.1–3.3):

* weights: asymmetric uniform, per-output-channel (or per-group g128) scale and
  zero-point, with learnable clipping of the min/max range (``alpha``/``beta``)
  and an optional rank-1 distribution-compensation term ``gamma * a b^T``
  folded into the weight before quantization (Eq. 3);
* activations / KV cache: symmetric per-token (per-head-token for KV) into a
  signed int8 container, regardless of the logical bit-width p <= 8;
* the *bit balance* strategy (§3.3): an n-bit balanced grid uses the symmetric
  level set {-2^{n-1}, ..., -1, 0, 1, ..., 2^{n-1}} (2^n + 1 levels), stored in
  ceil(log2(2^n + 1)) bit-planes.

Everything is pure-functional jnp; fake-quant paths use a straight-through
estimator so calibration gradients flow to the learnable parameters.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one quantization grid.

    Attributes:
      bits: logical bit-width n (1..8).
      symmetric: symmetric signed grid (activations) vs asymmetric unsigned
        grid with zero-point (weights).
      bit_balance: use the paper's balanced 2^n + 1 level grid (W n* configs).
        Implies a symmetric grid centred at 0.
      granularity: one of 'per_tensor' | 'per_channel' | 'per_token' |
        'per_group'.
      group_size: contraction-dim group size for 'per_group' (paper: 128).
      channel_axis: which axis carries the quantization channels. For weights
        stored (in_features, out_features) this is 1; for per-token activations
        (..., features) the scales live on all leading axes (axis = -1 reduced).
    """

    bits: int = 8
    symmetric: bool = False
    bit_balance: bool = False
    granularity: str = "per_channel"
    group_size: int = 128
    channel_axis: int = 1

    def __post_init__(self):
        if not (1 <= self.bits <= 8):
            raise ValueError(f"bits must be in [1, 8], got {self.bits}")
        if self.granularity not in (
            "per_tensor",
            "per_channel",
            "per_token",
            "per_group",
        ):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.bit_balance and self.bits >= 8:
            raise ValueError("bit_balance with bits>=8 overflows the int8 container")

    # ---- grid geometry -------------------------------------------------
    @property
    def qmax_abs(self) -> int:
        """Largest magnitude on a symmetric grid."""
        if self.bit_balance:
            return 2 ** (self.bits - 1)  # {-2^{n-1} .. 2^{n-1}}, 2^n+1 levels
        return 2 ** (self.bits - 1) - 1 if self.bits > 1 else 1

    @property
    def num_levels(self) -> int:
        if self.bit_balance:
            return 2**self.bits + 1
        return 2**self.bits

    @property
    def storage_bits(self) -> int:
        """Bit-planes needed to store the unsigned level index."""
        return max(1, math.ceil(math.log2(self.num_levels)))

    @property
    def level_min(self) -> int:
        """Smallest unsigned stored level (always 0)."""
        return 0

    @property
    def level_max(self) -> int:
        return self.num_levels - 1

    @property
    def default_zero_point(self) -> int:
        """Zero point for symmetric grids stored unsigned."""
        if self.bit_balance:
            return 2 ** (self.bits - 1)
        if self.symmetric:
            return 2 ** (self.bits - 1) - 1 if self.bits > 1 else 1
        return 0  # asymmetric: computed from data


# ---------------------------------------------------------------------------
# scale / zero-point computation
# ---------------------------------------------------------------------------


def _reduce_axes_for(spec: QuantSpec, ndim: int) -> tuple:
    """Axes reduced when computing scales."""
    if spec.granularity == "per_tensor":
        return tuple(range(ndim))
    if spec.granularity == "per_token":
        return (ndim - 1,)  # reduce over features, keep token axes
    if spec.granularity == "per_channel":
        ax = spec.channel_axis % ndim
        return tuple(i for i in range(ndim) if i != ax)
    raise ValueError(f"per_group handled separately; got {spec.granularity}")


def weight_scales(
    w: Array,
    spec: QuantSpec,
    alpha: Optional[Array] = None,
    beta: Optional[Array] = None,
) -> tuple[Array, Array]:
    """Per-channel (or per-tensor/group) scale + zero point for a weight.

    ``alpha``/``beta`` are the paper's learnable clipping parameters:
    ``w_max = alpha * max(w)``, ``w_min = beta * min(w)`` (per channel).
    They enter through a sigmoid in the calibration parametrization; here we
    accept them already in (0, 1]-ish space and simply multiply.

    Returns (scale, zero_point) broadcastable against ``w``; zero_point is a
    float during calibration (rounded only at packing time).
    """
    if spec.granularity == "per_group":
        return _group_scales(w, spec, alpha, beta)
    axes = _reduce_axes_for(spec, w.ndim)
    wmax = jnp.max(w, axis=axes, keepdims=True)
    wmin = jnp.min(w, axis=axes, keepdims=True)
    if alpha is not None:
        wmax = wmax * alpha
    if beta is not None:
        wmin = wmin * beta
    if spec.symmetric or spec.bit_balance:
        amax = jnp.maximum(jnp.abs(wmax), jnp.abs(wmin))
        scale = jnp.maximum(amax, _EPS) / spec.qmax_abs
        zp = jnp.full_like(scale, float(spec.default_zero_point))
        return scale, zp
    # asymmetric: grid [0, 2^n - 1]
    wmax = jnp.maximum(wmax, wmin + _EPS)  # degenerate-range guard
    scale = (wmax - wmin) / (spec.num_levels - 1)
    scale = jnp.maximum(scale, _EPS)
    zp = -wmin / scale
    return scale, zp


def _group_scales(w, spec, alpha, beta):
    """Per-group scales: contraction dim (axis 0 for (in, out) weights) is
    split into groups of ``group_size``; each (group, out-channel) cell gets
    its own scale/zp. Returned with a leading broadcastable layout
    ``(n_groups, 1, out)`` against ``w`` reshaped (n_groups, gs, out)."""
    k, n = w.shape
    gs = spec.group_size
    if k % gs != 0:
        raise ValueError(f"in_features {k} not divisible by group_size {gs}")
    wg = w.reshape(k // gs, gs, n)
    wmax = jnp.max(wg, axis=1, keepdims=True)
    wmin = jnp.min(wg, axis=1, keepdims=True)
    if alpha is not None:
        wmax = wmax * alpha
    if beta is not None:
        wmin = wmin * beta
    if spec.symmetric or spec.bit_balance:
        amax = jnp.maximum(jnp.abs(wmax), jnp.abs(wmin))
        scale = jnp.maximum(amax, _EPS) / spec.qmax_abs
        zp = jnp.full_like(scale, float(spec.default_zero_point))
        return scale, zp
    wmax = jnp.maximum(wmax, wmin + _EPS)
    scale = jnp.maximum((wmax - wmin) / (spec.num_levels - 1), _EPS)
    zp = -wmin / scale
    return scale, zp


def act_scales(x: Array, spec: QuantSpec) -> Array:
    """Symmetric per-token (or per-tensor) activation scale."""
    if spec.granularity == "per_token":
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    elif spec.granularity == "per_tensor":
        amax = jnp.max(jnp.abs(x))
    else:
        raise ValueError(
            f"activations support per_token/per_tensor, got {spec.granularity}"
        )
    return jnp.maximum(amax, _EPS) / spec.qmax_abs


# ---------------------------------------------------------------------------
# quantize / dequantize / fake-quant
# ---------------------------------------------------------------------------


def quantize_weight(
    w: Array, scale: Array, zp: Array, spec: QuantSpec
) -> Array:
    """w -> unsigned integer levels in [0, num_levels-1] (int32)."""
    if spec.granularity == "per_group":
        k, n = w.shape
        wg = w.reshape(k // spec.group_size, spec.group_size, n)
        q = jnp.round(wg / scale + zp)
        q = jnp.clip(q, 0, spec.level_max)
        return q.reshape(k, n).astype(jnp.int32)
    q = jnp.round(w / scale + zp)
    q = jnp.clip(q, 0, spec.level_max)
    return q.astype(jnp.int32)


def dequantize_weight(q: Array, scale: Array, zp: Array, spec: QuantSpec) -> Array:
    if spec.granularity == "per_group":
        k, n = q.shape
        qg = q.reshape(k // spec.group_size, spec.group_size, n).astype(scale.dtype)
        return ((qg - zp) * scale).reshape(k, n)
    return (q.astype(scale.dtype) - zp) * scale


def quantize_act(x: Array, scale: Array, spec: QuantSpec) -> Array:
    """x -> signed int8 container values in [-qmax_abs, qmax_abs]."""
    q = jnp.round(x / scale)
    lo = -float(spec.qmax_abs) if (spec.symmetric or spec.bit_balance) else 0.0
    if spec.bits == 8 and spec.symmetric and not spec.bit_balance:
        lo = -127.0  # keep -128 free: exactness under negation
    q = jnp.clip(q, lo, float(spec.qmax_abs))
    return q.astype(jnp.int8)


def dequantize_act(q: Array, scale: Array) -> Array:
    return q.astype(scale.dtype) * scale


def _ste_round(x: Array) -> Array:
    """Straight-through round: identity gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant_weight(
    w: Array,
    spec: QuantSpec,
    alpha: Optional[Array] = None,
    beta: Optional[Array] = None,
) -> Array:
    """Differentiable quantize->dequantize for calibration (STE round).

    Gradients flow to ``w`` (identity through round/clip interior) and to
    ``alpha``/``beta`` through the scale computation.
    """
    scale, zp = weight_scales(w, spec, alpha, beta)
    if spec.granularity == "per_group":
        k, n = w.shape
        wg = w.reshape(k // spec.group_size, spec.group_size, n)
        q = jnp.clip(_ste_round(wg / scale + zp), 0, spec.level_max)
        return ((q - zp) * scale).reshape(k, n)
    q = jnp.clip(_ste_round(w / scale + zp), 0, spec.level_max)
    return (q - zp) * scale


def fake_quant_act(x: Array, spec: QuantSpec) -> Array:
    scale = act_scales(x, spec)
    lo = -float(spec.qmax_abs)
    if spec.bits == 8 and not spec.bit_balance:
        lo = -127.0
    q = jnp.clip(_ste_round(x / scale), lo, float(spec.qmax_abs))
    return q * scale


# ---------------------------------------------------------------------------
# packed weight container used by the serving path / kernels
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedWeight:
    """Offline-quantized weight in bit-plane form.

    Attributes:
      planes: uint32 [n_planes, K/32, N] bit-packed binary matrices
        (plane s holds bit s of the unsigned level index).
      scale: fp32 per-channel scale, broadcastable to (K, N) -> shape (1, N)
        or per-group (K/gs, 1, N).
      zero_point: fp32 zero point, same shape as scale.
      bits: logical bit-width (for bookkeeping; n_planes = storage bits).
      k: unpadded contraction length.
    """

    planes: Array
    scale: Array
    zero_point: Array
    bits: int
    k: int

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (
            (ga("planes"), self.planes),
            (ga("scale"), self.scale),
            (ga("zero_point"), self.zero_point),
        ), (self.bits, self.k)

    def tree_flatten(self):
        return (self.planes, self.scale, self.zero_point), (self.bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        planes, scale, zp = children
        bits, k = aux
        return cls(planes, scale, zp, bits, k)

    @property
    def n_planes(self) -> int:
        return self.planes.shape[0]

    @property
    def out_features(self) -> int:
        return self.planes.shape[-1]

    def nbytes(self) -> int:
        return (
            self.planes.size * 4 + self.scale.size * 4 + self.zero_point.size * 4
        )


def pack_weight(
    w: Array,
    spec: QuantSpec,
    alpha: Optional[Array] = None,
    beta: Optional[Array] = None,
    compensation: Optional[Array] = None,
) -> PackedWeight:
    """Quantize ``w`` (K, N) offline and pack into bit-planes.

    ``compensation`` is the paper's rank-1 term ``a b^T`` (already formed),
    added to w before quantization (Eq. 3 with gamma = 1).
    """
    from repro.core import bitplane  # local import to avoid cycle

    if compensation is not None:
        w = w + compensation
    scale, zp = weight_scales(w, spec, alpha, beta)
    q = quantize_weight(w, scale, zp, spec)
    planes = bitplane.pack_bitplanes(q, spec.storage_bits)
    # squeeze the keepdims scale down to a canonical broadcast shape
    return PackedWeight(
        planes=planes,
        scale=scale.astype(jnp.float32),
        zero_point=zp.astype(jnp.float32),
        bits=spec.bits,
        k=w.shape[0],
    )

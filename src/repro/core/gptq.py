"""GPTQ baseline (Frantar et al. 2022) — the paper's weight-only comparator.

Hessian-based error compensation: quantize the weight one input-dimension at
a time, distributing each dimension's rounding error onto the not-yet-
quantized dimensions through the inverse Hessian of the layerwise
reconstruction objective  H = 2·XᵀX + λI.

Offline, numpy-based (runs once per linear at packing time, like the
paper's baselines). Our weight layout is (K=in, N=out); GPTQ walks K.
"""

from __future__ import annotations

import numpy as np

from repro.core.quantizers import QuantSpec


def gptq_quantize(
    w: np.ndarray,  # (K, N) fp32
    x_calib: np.ndarray,  # (T, K) calibration inputs to this linear
    spec: QuantSpec,
    percdamp: float = 0.01,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (levels (K,N) int32, scale (1,N), zp (1,N))."""
    w = np.asarray(w, np.float64).copy()
    k, n = w.shape

    h = 2.0 * (x_calib.astype(np.float64).T @ x_calib.astype(np.float64))
    damp = percdamp * np.mean(np.diag(h)) + 1e-8
    h[np.diag_indices(k)] += damp

    # per-output-channel scales from the raw weight range
    if spec.symmetric or spec.bit_balance:
        amax = np.abs(w).max(axis=0, keepdims=True)
        scale = np.maximum(amax, 1e-8) / spec.qmax_abs
        zp = np.full((1, n), float(spec.default_zero_point))
    else:
        wmax = w.max(axis=0, keepdims=True)
        wmin = w.min(axis=0, keepdims=True)
        scale = np.maximum((wmax - wmin) / (spec.num_levels - 1), 1e-8)
        zp = -wmin / scale

    # explicit OBS loop: quantize dim i, push the rounding error onto the
    # not-yet-quantized dims through the (downdated) inverse Hessian.
    hinv = np.linalg.inv(h)
    q_levels = np.zeros((k, n), np.int32)
    for i in range(k):
        wi = w[i, :]
        qi = np.clip(np.round(wi / scale[0] + zp[0]), 0, spec.level_max)
        q_levels[i] = qi.astype(np.int32)
        dq = (qi - zp[0]) * scale[0]
        e = wi - dq
        d = hinv[i, i]
        if i + 1 < k and d > 1e-12:
            col = hinv[i + 1:, i]
            w[i + 1:, :] -= np.outer(col / d, e)
            # rank-1 downdate: inverse of the remaining submatrix
            hinv_next = hinv[i + 1:, i + 1:] - np.outer(col, col) / d
            hinv = np.zeros((k, k))
            hinv[i + 1:, i + 1:] = hinv_next
    return q_levels, scale.astype(np.float32), zp.astype(np.float32)


def gptq_pack_linear(w, x_calib, spec: QuantSpec):
    """GPTQ-quantize then bit-plane pack -> PackedWeight (serving format)."""
    import jax.numpy as jnp

    from repro.core.bitplane import pack_bitplanes
    from repro.core.quantizers import PackedWeight

    levels, scale, zp = gptq_quantize(np.asarray(w, np.float32),
                                      np.asarray(x_calib, np.float32), spec)
    planes = pack_bitplanes(jnp.asarray(levels), spec.storage_bits)
    return PackedWeight(planes=planes, scale=jnp.asarray(scale),
                        zero_point=jnp.asarray(zp), bits=spec.bits,
                        k=w.shape[0])

"""Block-wise ABQ-LLM calibration (paper §3.1–3.2, §4.1).

For each transformer block i, learn
  * balance vector ``s`` (per in-channel, log-parametrized; init from the
    SmoothQuant rule),
  * weight clipping ``α, β`` (per out-channel, sigmoid-parametrized, init≈1),
  * distribution-compensation vectors ``a, b`` (rank-1 ``γ·a bᵀ`` on the
    down_proj weight; trained only for the first and last blocks — γ there
    is 1, everywhere else the zero-init of ``b`` keeps it inert),
minimizing  L = DLC(d_q, d_fp, d_fp*) + AKL(attn_q ‖ attn_fp)  (Eq. 5)
with AdamW (no weight decay), lr 5e-3 for s and 1e-2 for clip/compensation,
over calibration segments, exactly the paper's §4.1 recipe (epochs/segments
scaled by the caller; defaults here are CPU-sized).

The quantized stream is propagated block to block (d_fp* uses the fp block on
the quantized stream), so later blocks calibrate against realistic inputs.

Works per-family:
  dense/moe blocks — DLC + AKL (attention maps from the reference path);
  ssm blocks       — DLC only (attention-free; DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.losses import akl_loss, block_mse, dlc_loss
from repro.core.quantizers import (
    QuantSpec,
    fake_quant_act,
    fake_quant_weight,
)
from repro.optim import adamw

Array = jax.Array

_ATTN_LINEARS = ("wq", "wk", "wv", "wo")
_MLP_LINEARS = ("w_gate", "w_up", "w_down")
_SSM_LINEARS = ("wz", "wx", "wB", "wC", "wdt", "wout")


@dataclasses.dataclass(frozen=True)
class CalibConfig:
    w_bits: int = 4
    a_bits: int = 4
    bit_balance: bool = False
    epochs: int = 20
    lr_balance: float = 5e-3
    lr_clip: float = 1e-2
    loss: str = "dlc_akl"  # "dlc_akl" (paper) | "mse" (OmniQuant-style ablation)
    akl_weight: float = 1.0
    group_size: int = 0

    @property
    def wspec(self) -> QuantSpec:
        return QuantSpec(
            bits=self.w_bits,
            bit_balance=self.bit_balance,
            granularity="per_group" if self.group_size else "per_channel",
            group_size=self.group_size or 128,
            channel_axis=1,
        )

    @property
    def aspec(self) -> QuantSpec:
        return QuantSpec(bits=self.a_bits, symmetric=True, granularity="per_token")


# ---------------------------------------------------------------------------
# learnable quant-state init
# ---------------------------------------------------------------------------


def _init_linear_qstate(w: Array, with_comp: bool,
                        s_init: Optional[Array] = None) -> dict:
    k, n = w.shape
    st = {
        "log_s": jnp.zeros((k,), jnp.float32) if s_init is None
        else jnp.log(jnp.maximum(s_init, 1e-5)),
        # sigmoid(6.0) ≈ 0.9975 ≈ the paper's clip-init of 1
        "alpha_raw": jnp.full((n,), 6.0, jnp.float32),
        "beta_raw": jnp.full((n,), 6.0, jnp.float32),
    }
    if with_comp:
        st["comp_a"] = jnp.ones((k,), jnp.float32)
        st["comp_b"] = jnp.zeros((n,), jnp.float32)
    return st


def smoothquant_s_init(act_amax: Array, w: Array, alpha: float = 0.5) -> Array:
    """SmoothQuant balance init: s_k = amax_x(k)^α / amax_w(k)^(1-α).

    (Our convention scales the *weight* by s and divides the activation.)
    """
    w_amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1)
    s_act = jnp.power(jnp.maximum(act_amax, 1e-5), alpha)
    s_w = jnp.power(jnp.maximum(w_amax, 1e-5), 1.0 - alpha)
    # activation divided by (s_act/s_w): our log_s stores the weight-side mult
    return jnp.maximum(s_act / s_w, 1e-5)


def init_block_qstate(block_params: dict, *, edge_block: bool,
                      act_stats: Optional[dict] = None) -> dict:
    """Create the learnable quant state mirroring one block's linears."""

    def for_group(group: dict, names, group_name: str) -> dict:
        out = {}
        for name in names:
            if name not in group:
                continue
            w = group[name]
            with_comp = name == "w_down"  # compensation targets down_proj
            s_init = None
            if act_stats is not None:
                s_init_amax = act_stats.get(group_name, {}).get(name)
                if s_init_amax is not None:
                    s_init = smoothquant_s_init(s_init_amax, w)
            out[name] = _init_linear_qstate(w, with_comp, s_init)
        return out

    qstate: dict[str, Any] = {}
    if "attn" in block_params:
        qstate["attn"] = for_group(block_params["attn"], _ATTN_LINEARS, "attn")
    if "mlp" in block_params:
        qstate["mlp"] = for_group(block_params["mlp"], _MLP_LINEARS, "mlp")
    if "ssm" in block_params:
        qstate["ssm"] = for_group(block_params["ssm"], _SSM_LINEARS, "ssm")
    if "moe" in block_params and "shared" in block_params["moe"]:
        qstate["moe"] = {
            "shared": for_group(block_params["moe"]["shared"], _MLP_LINEARS,
                                "moe_shared")
        }
    return qstate


def lr_tree_for(qstate, cfg: CalibConfig, *, edge_block: bool):
    """Per-leaf LR: balance 5e-3; clip + compensation 1e-2; compensation is
    frozen (lr 0 — the paper's γ=0) on non-edge blocks."""

    def leaf_lr(key):
        if key == "log_s":
            return cfg.lr_balance
        if key in ("comp_a", "comp_b"):
            return cfg.lr_clip if edge_block else 0.0
        return cfg.lr_clip

    def walk(node):
        return {
            k: walk(v) if isinstance(v, dict) else leaf_lr(k)
            for k, v in node.items()
        }

    return walk(qstate)


# ---------------------------------------------------------------------------
# fake-quant forward of one block
# ---------------------------------------------------------------------------


def fq_linear(x: Array, w: Array, qp: Optional[dict], cfg: CalibConfig) -> Array:
    """Differentiable quantized linear with the learnable parametrization."""
    if qp is None:
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    wf = w.astype(jnp.float32)
    s = jnp.exp(qp["log_s"])
    xb = x.astype(jnp.float32) / s
    wb = wf * s[:, None]
    if "comp_a" in qp:
        wb = wb + jnp.outer(qp["comp_a"], qp["comp_b"])
    alpha = jax.nn.sigmoid(qp["alpha_raw"])
    beta = jax.nn.sigmoid(qp["beta_raw"])
    wq = fake_quant_weight(wb, cfg.wspec, alpha=alpha, beta=beta)
    xq = fake_quant_act(xb, cfg.aspec)
    return (xq @ wq).astype(x.dtype)


def _fq_or_fp(quant: bool):
    def apply(x, w, qp, cfg):
        if quant and qp is not None:
            return fq_linear(x, w, qp, cfg)
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))

    return apply


def block_apply_fq(
    block_params: dict,
    qstate: Optional[dict],
    x: Array,
    arch_cfg,
    calib_cfg: CalibConfig,
    *,
    quant: bool,
    return_attn: bool = True,
):
    """Forward one block in fp (quant=False) or fake-quant mode.

    Returns (out, attn_probs_or_None). Supports dense / moe(shared-expert
    fq; routed experts fp during calibration — they are RTN'd at packing) /
    ssm blocks.
    """
    from repro.models import ssm as ssm_mod
    from repro.models.blocks import ModelContext
    from repro.models.layers import activation, rms_norm
    from repro.models import attention as attn_mod

    lin = _fq_or_fp(quant)
    ctx = ModelContext(cfg=arch_cfg, remat=False)
    qs = qstate or {}

    if "ssm" in block_params:  # mamba block: DLC only
        h = rms_norm(x, block_params["norm"], arch_cfg.norm_eps)
        p = block_params["ssm"]
        q = qs.get("ssm", {})
        b, s_len, _ = h.shape
        nh, hd_, ns = arch_cfg.ssm_heads, arch_cfg.ssm_headdim, arch_cfg.ssm_state
        z = lin(h, p["wz"], q.get("wz"), calib_cfg)
        xs = lin(h, p["wx"], q.get("wx"), calib_cfg)
        Bm = lin(h, p["wB"], q.get("wB"), calib_cfg)
        Cm = lin(h, p["wC"], q.get("wC"), calib_cfg)
        dt_raw = lin(h, p["wdt"], q.get("wdt"), calib_cfg)
        xs, _ = ssm_mod._causal_conv(xs, p["conv_x"])
        Bm, _ = ssm_mod._causal_conv(Bm, p["conv_B"])
        Cm, _ = ssm_mod._causal_conv(Cm, p["conv_C"])
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        a_ = -jnp.exp(p["A_log"])
        xh = xs.reshape(b, s_len, nh, hd_)
        y = ssm_mod._ssd_chunked(xh, dt, a_, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), arch_cfg.ssm_chunk)
        y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, s_len, arch_cfg.d_inner).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rms_norm(y, p["norm"], arch_cfg.norm_eps)
        out = lin(y, p["wout"], q.get("wout"), calib_cfg)
        return x + out, None

    # attention block (dense / moe)
    h = rms_norm(x, block_params["attn_norm"], arch_cfg.norm_eps)
    ap = block_params["attn"]
    aq = qs.get("attn", {})
    b, s_len, _ = h.shape
    hd = arch_cfg.resolved_head_dim
    qv = lin(h, ap["wq"], aq.get("wq"), calib_cfg).reshape(
        b, s_len, arch_cfg.n_heads, hd)
    kv = lin(h, ap["wk"], aq.get("wk"), calib_cfg).reshape(
        b, s_len, arch_cfg.n_kv_heads, hd)
    vv = lin(h, ap["wv"], aq.get("wv"), calib_cfg).reshape(
        b, s_len, arch_cfg.n_kv_heads, hd)
    if arch_cfg.qk_norm:
        qv = rms_norm(qv, ap["q_norm"], arch_cfg.norm_eps)
        kv = rms_norm(kv, ap["k_norm"], arch_cfg.norm_eps)
    from repro.models.layers import apply_rope

    pos = jnp.arange(s_len)
    qv = apply_rope(qv, pos, arch_cfg.rope_theta)
    kv = apply_rope(kv, pos, arch_cfg.rope_theta)
    rep = arch_cfg.n_heads // arch_cfg.n_kv_heads
    kk = jnp.repeat(kv, rep, axis=2)
    vx = jnp.repeat(vv, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qv.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (hd**0.5)
    mask = jnp.tril(jnp.ones((s_len, s_len), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    att = jnp.einsum("bhqk,bkhd->bqhd", probs, vx.astype(jnp.float32))
    att = att.astype(x.dtype).reshape(b, s_len, arch_cfg.n_heads * hd)
    att = lin(att, ap["wo"], aq.get("wo"), calib_cfg)
    x = x + att

    h = rms_norm(x, block_params["mlp_norm"], arch_cfg.norm_eps)
    if "mlp" in block_params:
        mp = block_params["mlp"]
        mq = qs.get("mlp", {})
        g = lin(h, mp["w_gate"], mq.get("w_gate"), calib_cfg)
        if "w_up" in mp:
            u = lin(h, mp["w_up"], mq.get("w_up"), calib_cfg)
            hid = activation(g, arch_cfg.act) * u
        else:
            hid = activation(g, arch_cfg.act)
        m = lin(hid, mp["w_down"], mq.get("w_down"), calib_cfg)
    else:  # moe: shared experts fake-quant; routed experts fp here
        from repro.models import moe as moe_mod

        m, _ = moe_mod.moe_ffn(block_params["moe"], h, arch_cfg, mesh=None)
        if "moe" in qs and "shared" in block_params["moe"]:
            sp = block_params["moe"]["shared"]
            sq = qs["moe"]["shared"]
            g = lin(h, sp["w_gate"], sq.get("w_gate"), calib_cfg)
            u = lin(h, sp["w_up"], sq.get("w_up"), calib_cfg)
            hid = activation(g, arch_cfg.act) * u
            m_shared_fq = lin(hid, sp["w_down"], sq.get("w_down"), calib_cfg)
            # replace the fp shared contribution with the fq one
            g0 = jnp.einsum("...k,kn->...n", h, sp["w_gate"].astype(h.dtype))
            u0 = jnp.einsum("...k,kn->...n", h, sp["w_up"].astype(h.dtype))
            m_shared_fp = jnp.einsum(
                "...k,kn->...n", activation(g0, arch_cfg.act) * u0,
                sp["w_down"].astype(h.dtype))
            m = m - m_shared_fp + m_shared_fq
    x = x + m
    return x, probs


# ---------------------------------------------------------------------------
# per-block calibration loop
# ---------------------------------------------------------------------------


def calibrate_block(
    block_params: dict,
    x_q_in: Array,  # (n_seg, B, S, D) quantized-stream inputs
    x_fp_in: Array,  # (n_seg, B, S, D) fp-stream inputs
    arch_cfg,
    cfg: CalibConfig,
    *,
    edge_block: bool,
    act_stats: Optional[dict] = None,
) -> tuple[dict, Array, Array]:
    """Calibrate one block. Returns (qstate, new q-stream, new fp-stream)."""
    # Compensation vectors exist in every block's state (uniform structure,
    # so per-block states stack into one tree for vectorized packing) but are
    # frozen (lr 0 == the paper's γ=0) except on the first/last block.
    qstate = init_block_qstate(block_params, edge_block=edge_block,
                               act_stats=act_stats)
    opt_cfg = adamw.AdamWConfig(lr=cfg.lr_clip, weight_decay=0.0)
    opt_state = adamw.init(qstate, opt_cfg)
    lr_tree = lr_tree_for(qstate, cfg, edge_block=edge_block)
    has_attn = "attn" in block_params
    use_akl = cfg.loss == "dlc_akl" and has_attn

    def loss_fn(qs, xq, xfp):
        d_q, attn_q = block_apply_fq(block_params, qs, xq, arch_cfg, cfg,
                                     quant=True, return_attn=use_akl)
        d_fp, attn_fp = block_apply_fq(block_params, None, xfp, arch_cfg, cfg,
                                       quant=False, return_attn=use_akl)
        d_fp_star, _ = block_apply_fq(block_params, None, xq, arch_cfg, cfg,
                                      quant=False, return_attn=False)
        if cfg.loss == "mse":
            return block_mse(d_q.astype(jnp.float32), d_fp.astype(jnp.float32))
        total = dlc_loss(d_q.astype(jnp.float32), d_fp.astype(jnp.float32),
                         d_fp_star.astype(jnp.float32))
        if use_akl and attn_q is not None:
            total = total + cfg.akl_weight * akl_loss(attn_q, attn_fp)
        return total

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def opt_step(qs, opt_s, grads):
        return adamw.update(grads, opt_s, qs, opt_cfg, lr_tree=lr_tree)

    n_seg = x_q_in.shape[0]
    for _ in range(cfg.epochs):
        for i in range(n_seg):
            _, grads = grad_fn(qstate, x_q_in[i], x_fp_in[i])
            qstate, opt_state = opt_step(qstate, opt_state, grads)

    # propagate streams
    @jax.jit
    def fwd_q(xq):
        return block_apply_fq(block_params, qstate, xq, arch_cfg, cfg,
                              quant=True, return_attn=False)[0]

    @jax.jit
    def fwd_fp(xfp):
        return block_apply_fq(block_params, None, xfp, arch_cfg, cfg,
                              quant=False, return_attn=False)[0]

    new_q = jnp.stack([fwd_q(x_q_in[i]) for i in range(n_seg)])
    new_fp = jnp.stack([fwd_fp(x_fp_in[i]) for i in range(n_seg)])
    return qstate, new_q, new_fp


def calibrate_model(
    params: dict,
    calib_tokens: Array,  # (n_seg, B, S) int32
    arch_cfg,
    cfg: CalibConfig,
    *,
    collect_act_stats: bool = True,
) -> list[dict]:
    """Sequential block-wise calibration over the whole model.

    Returns a list of per-block qstates (length n_layers) that
    `repro.models.quantized.quantize_model` consumes after tree-stacking.
    Supports the uniform-stack families (dense/moe/ssm); hybrid/vlm calibrate
    their uniform sub-stacks the same way (edge = first/last of the stack).
    """
    from repro.models import lm as lm_mod
    from repro.models.blocks import ModelContext

    ctx = ModelContext(cfg=arch_cfg, remat=False)
    n_seg = calib_tokens.shape[0]
    embeds = jnp.stack([
        lm_mod.embed_tokens(params, calib_tokens[i], arch_cfg, ctx)
        for i in range(n_seg)
    ])
    x_q = embeds
    x_fp = embeds
    n_layers = arch_cfg.n_layers
    states = []
    for layer in range(n_layers):
        block_params = jax.tree.map(lambda a: a[layer], params["blocks"])
        act_stats = (
            _collect_act_stats(block_params, x_fp, arch_cfg)
            if collect_act_stats else None
        )
        qstate, x_q, x_fp = calibrate_block(
            block_params, x_q, x_fp, arch_cfg, cfg,
            edge_block=(layer == 0 or layer == n_layers - 1),
            act_stats=act_stats,
        )
        states.append(qstate)
    return states


def stack_qstates(states: list[dict]) -> dict:
    """Per-block qstate list -> stacked tree for quantize_model."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _collect_act_stats(block_params, x_fp, arch_cfg) -> dict:
    """Per-linear input absmax (for the SmoothQuant s-init), from the fp
    stream. Only the block-input-fed linears need stats; inner ones reuse
    the block input amax as a cheap proxy."""
    from repro.models.layers import rms_norm

    x0 = x_fp.reshape(-1, x_fp.shape[-1]).astype(jnp.float32)
    stats: dict[str, dict[str, Array]] = {}
    if "attn" in block_params:
        h = rms_norm(x0, block_params["attn_norm"], arch_cfg.norm_eps)
        amax = jnp.max(jnp.abs(h), axis=0)
        stats["attn"] = {
            k: amax for k in _ATTN_LINEARS
            if k in block_params["attn"]
            and block_params["attn"][k].shape[0] == amax.shape[0]
        }  # wq/wk/wv see the block input; wo (K = H·hd) has no stats -> s=1
        h2 = rms_norm(x0, block_params["mlp_norm"], arch_cfg.norm_eps)
        amax2 = jnp.max(jnp.abs(h2), axis=0)
        if "mlp" in block_params:
            stats["mlp"] = {
                k: amax2 for k in _MLP_LINEARS
                if k in block_params["mlp"]
                and block_params["mlp"][k].shape[0] == amax2.shape[0]
            }  # w_down (K = ff) has no stats -> s=1, learnable
    elif "ssm" in block_params:
        h = rms_norm(x0, block_params["norm"], arch_cfg.norm_eps)
        amax = jnp.max(jnp.abs(h), axis=0)
        stats["ssm"] = {k: amax for k in ("wz", "wx", "wB", "wC", "wdt")
                        if k in block_params["ssm"]}
    return stats

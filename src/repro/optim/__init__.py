from repro.optim.adamw import AdamWConfig, global_norm, init, update
from repro.optim.schedule import constant, cosine_with_warmup

__all__ = [
    "AdamWConfig",
    "constant",
    "cosine_with_warmup",
    "global_norm",
    "init",
    "update",
]

"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, base_lr: float, warmup: int, total: int,
                       final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac * base_lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * (final_frac + (1 - final_frac) * cos)


def constant(step, *, base_lr: float):
    return jnp.full_like(jnp.asarray(step, jnp.float32), base_lr)

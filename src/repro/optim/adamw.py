"""AdamW, pure-functional, with dtype-configurable moments and per-leaf LRs.

Used both for model training (bf16 moments at 100B+ scale — see DESIGN.md §4)
and for the paper's block-wise calibration (§4.1: AdamW, no weight decay,
lr 5e-3 for balance vectors / 1e-2 for clipping + compensation — expressed
here as a per-leaf learning-rate pytree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: Optional[str] = None  # None -> param dtype; "bfloat16" at scale
    grad_clip_norm: Optional[float] = None


def init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    def make_moment(p):
        dt = p.dtype if cfg.moment_dtype is None else jnp.dtype(cfg.moment_dtype)
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(make_moment, params),
        "v": jax.tree.map(make_moment, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def update(
    grads: PyTree,
    state: PyTree,
    params: PyTree,
    cfg: AdamWConfig,
    lr_scale: Union[float, Array] = 1.0,
    lr_tree: Optional[PyTree] = None,
) -> tuple[PyTree, PyTree]:
    """One AdamW step. ``lr_tree`` (if given) holds a per-leaf LR that
    overrides cfg.lr; ``lr_scale`` multiplies either (schedules)."""
    step = state["step"] + 1
    if cfg.grad_clip_norm is not None:
        gn = global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * clip, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, lr):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * lr_scale * step_
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    if lr_tree is None:
        lr_tree = jax.tree.map(lambda _: cfg.lr, params)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_lr = treedef.flatten_up_to(lr_tree)

    out = [upd(g, m, v, p, lr) for g, m, v, p, lr in zip(flat_g, flat_m, flat_v, flat_p, flat_lr)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}

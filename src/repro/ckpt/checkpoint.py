"""Sharded, atomic, resumable checkpoints with elastic re-sharding.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step
            arrays.npz          one entry per leaf (keyed by tree path)
         <dir>/step_<N>.tmp/    staging dir (atomic rename on completion)

Properties the 1000-node story needs:
  * atomic: a crash mid-save never corrupts the latest checkpoint (tmp dir
    + rename; readers only see complete step_N dirs);
  * elastic: arrays are stored logically (unsharded); ``restore`` re-shards
    onto whatever mesh is live via device_put with the current NamedSharding
    — resuming 512-chip state on 256 chips (or 1 CPU in tests) just works;
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes to disk on a background thread, so the train loop continues; the
    next save joins the previous writer first;
  * self-describing: the manifest allows restore without constructing a
    template tree (useful for postmortem tooling), though restore_like is
    the fast path.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import numpy as np

_SEP = "/"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """npz can't round-trip ml_dtypes (bfloat16, ...) — store raw bytes."""
    if arr.dtype.kind == "V" or arr.dtype.name not in (
        "float64", "float32", "float16", "int64", "int32", "int16", "int8",
        "uint64", "uint32", "uint16", "uint8", "bool",
    ):
        return arr.view(np.uint8).reshape(-1)
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    dt = _np_dtype(dtype_name)
    if arr.dtype == np.uint8 and (dt.name != "uint8" or arr.shape != tuple(shape)):
        return np.frombuffer(arr.tobytes(), dtype=dt).reshape(shape)
    return arr.astype(dt).reshape(shape)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _flatten(tree) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    meta = {}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        key = _path_str(path)
        meta[key] = {"shape": list(arr.shape), "dtype": arr.dtype.name}
        out[key] = _to_storable(arr)
    return out, meta


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, meta = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "leaves": meta, "format": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk in the background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any):
        self.wait()
        arrays, meta = _flatten(tree)  # device->host copy happens here, sync

        def _write():
            os.makedirs(self.directory, exist_ok=True)
            final = os.path.join(self.directory, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {"step": step, "leaves": meta, "format": 1}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = all_steps(self.directory)
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_like(directory: str, step: int, template: Any,
                 shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``template``; re-shard onto the live
    mesh if ``shardings`` (a matching tree of NamedSharding) is given."""
    base = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(base, "arrays.npz"))
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for (p, leaf), shard in zip(leaves, shard_leaves):
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        meta = manifest["leaves"][key]
        arr = _from_storable(data[key], meta["dtype"], meta["shape"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != "
                f"template {leaf.shape}"
            )
        arr = arr.astype(_np_dtype(str(jax.numpy.dtype(leaf.dtype))))
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)

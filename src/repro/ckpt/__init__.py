from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    all_steps,
    latest_step,
    restore_like,
    save,
)

__all__ = ["AsyncCheckpointer", "all_steps", "latest_step", "restore_like", "save"]

"""Perplexity + synthetic zero-shot-style probes.

The paper evaluates on WikiText2/C4 perplexity and six zero-shot tasks. This
container has no internet/weights, so the benchmarks train a small LM on the
deterministic synthetic distribution (repro.data.synthetic) and evaluate:
  * ppl        — next-token perplexity on held-out synthetic segments
                 (paper's §4.2 analogue; sentence length = cfg.seq_len);
  * bucket_acc — accuracy of predicting the successor *bucket* (the planted
                 structure of the distribution), the analogue of the paper's
                 zero-shot accuracy tables (§4.3): a discriminative probe
                 that degrades with quantization the way task accuracy does.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.blocks import ModelContext


def perplexity(params, cfg: ArchConfig, ctx: ModelContext, *,
               n_batches: int = 4, batch: int = 4, seq_len: int = 128,
               seed: int = 1234) -> float:
    """Held-out = SAME planted distribution (same seed -> same transition
    structure), unseen sample indices (>= 10k; training uses < 4k)."""
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                seed=seed, n_codebooks=cfg.n_codebooks))
    total, count = 0.0, 0.0

    @jax.jit
    def nll(p, batch_):
        loss, _ = lm.loss_fn(p, batch_, cfg, ctx, n_loss_chunks=4)
        return loss

    for i in range(n_batches):
        b = ds.batch(10_000 + i, batch)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        total += float(nll(params, b))
        count += 1
    return float(np.exp(total / count))


def bucket_accuracy(params, cfg: ArchConfig, ctx: ModelContext, *,
                    n_batches: int = 2, batch: int = 4, seq_len: int = 64,
                    seed: int = 1234) -> float:
    """Fraction of positions where the argmax next-token falls in the true
    successor bucket of the current token (the planted transition)."""
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                seed=seed, n_codebooks=cfg.n_codebooks))
    bucket_of = ds._bucket_of

    @jax.jit
    def predict(p, tokens):
        h, _ = lm.forward_hidden(p, tokens, cfg, ctx)
        from repro.models.layers import rms_norm
        from repro.models.loss import logits_last_token

        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h,
                            lm.lm_head_weight(p, cfg).astype(h.dtype)) \
            if not isinstance(lm.lm_head_weight(p, cfg), jnp.ndarray) is None \
            else None
        return logits

    hits, total = 0, 0
    for i in range(n_batches):
        b = ds.batch(20_000 + i, batch)
        tokens = jnp.asarray(b["tokens"])
        h, _ = lm.forward_hidden(params, tokens, cfg, ctx)
        from repro.models.layers import apply_linear, rms_norm

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = apply_linear(h, lm.lm_head_weight(params, cfg))
        pred = np.asarray(jnp.argmax(logits[:, :-1, :cfg.vocab_size], axis=-1))
        cur = np.asarray(tokens[:, :-1])
        hits += int(np.sum(bucket_of[pred] == bucket_of[cur]))
        total += pred.size
    return hits / max(total, 1)

"""mamba2-2.7b [ssm]: 64L d_model=2560 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].
vocab padded to 50432 for 16-way TP sharding (DESIGN.md §4)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
)

SMOKE = ArchConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_headdim=16, ssm_chunk=16,
)

"""zamba2-7b [hybrid]: 81 Mamba2 layers + shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]. The shared transformer block (attn + MLP,
d_ff=14336) is applied every 6 ssm layers (weights shared across
applications — Zamba-style).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    shared_attn_every=6,
)

SMOKE = ArchConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
    shared_attn_every=2,
)

"""Architecture configs: the 10 assigned archs + the paper's LLaMA models.

Each ``<arch>.py`` exports ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family config for CPU smoke tests). The registry
maps ``--arch <id>`` CLI names to modules.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_REGISTRY = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "gemma-7b": "repro.configs.gemma_7b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "minitron-8b": "repro.configs.minitron_8b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "musicgen-large": "repro.configs.musicgen_large",
    # the paper's own evaluation models
    "llama-7b": "repro.configs.llama_7b",
}

ARCH_NAMES = tuple(_REGISTRY)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One transformer-family architecture (see DESIGN.md §6 for mapping)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU) | relu2 (squared ReLU)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert ffn width (if != d_ff)
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2): one shared attention block applied every N ssm layers
    shared_attn_every: int = 0
    # --- vlm (llama-3.2-vision): groups of (k self layers + 1 cross layer)
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # --- audio (musicgen): codebook heads over EnCodec tokens
    n_codebooks: int = 0
    # --- misc ---
    tie_embeddings: bool = False
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    vocab_pad_to: int = 256  # pad vocab so TP/vocab sharding divides

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab_size, self.vocab_pad_to
        return (v + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic (SSM/hybrid) families."""
        return self.family in ("ssm", "hybrid")

    def kv_heads_for_mesh(self, tensor_par: int) -> int:
        """Megatron-style KV-head replication so TP stays legal: the
        effective KV head count is lcm(kv, tp) — whole-head replication,
        divisible by the tensor axis. (kv=8 on a 16-way model axis -> 16.)"""
        kv = self.n_kv_heads
        if kv == 0:
            return 0
        import math

        eff = math.lcm(kv, max(tensor_par, 1))
        if self.n_heads % eff != 0:
            raise ValueError(
                f"{self.name}: q heads {self.n_heads} not divisible by "
                f"effective kv heads {eff} (tp={tensor_par})"
            )
        return eff

    def with_kv_replication(self, tensor_par: int) -> "ArchConfig":
        """Return a config whose kv heads are replicated for this TP degree.
        Param shapes change accordingly (the checkpoint loader replicates
        real kv heads on load, like Megatron)."""
        if self.n_kv_heads == 0:
            return self
        eff = self.kv_heads_for_mesh(tensor_par)
        if eff == self.n_kv_heads:
            return self
        return dataclasses.replace(self, n_kv_heads=eff)

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            assert self.n_heads > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_headdim == 0
        if self.family == "vlm":
            assert self.cross_attn_every > 0 and self.n_image_tokens > 0
        if self.family == "audio":
            assert self.n_codebooks > 0


# ---------------------------------------------------------------------------
# input-shape regimes (assigned): every LM arch pairs with all four
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = importlib.import_module(_REGISTRY[name]).CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = importlib.import_module(_REGISTRY[name]).SMOKE
    cfg.validate()
    return cfg


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell is part of the dry-run matrix.

    long_500k needs sub-quadratic attention (assignment spec): run for
    ssm/hybrid, skip for full-attention archs.
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "skipped(full-attention arch; 500k dense KV is the quadratic regime)"
    return True, ""

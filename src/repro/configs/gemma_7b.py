"""gemma-7b [dense]: 28L d_model=3072 16H (MHA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256, sqrt(d) embed scaling
[arXiv:2403.08295; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    act="gelu", embed_scale=True, tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="gemma-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=128, vocab_size=512,
    act="gelu", embed_scale=True,
)

"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; pruned nemotron — squared-ReLU plain MLP
[arXiv:2407.14679; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000, act="relu2",
)

SMOKE = ArchConfig(
    name="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, act="relu2",
)

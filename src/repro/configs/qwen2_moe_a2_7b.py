"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1408 vocab=151936, 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=512,
    n_experts=6, top_k=4, n_shared_experts=2, moe_d_ff=96,
)

"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 per codebook; decoder-only over EnCodec tokens (4 codebooks,
delay pattern); the EnCodec encoder/decoder is a stub — input_specs provides
the token streams [arXiv:2306.05284; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, n_codebooks=4,
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128, n_codebooks=4,
)

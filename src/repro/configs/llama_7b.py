"""llama-7b [dense]: the paper's primary evaluation model.
32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000 [arXiv:2302.13971]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=32000,
)

SMOKE = ArchConfig(
    name="llama-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
)

"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; gated cross-attn image layers every 5th layer
(80 self + 20 cross); vision frontend is a stub — input_specs provides
precomputed patch embeddings (B, 1024, d_model)
[hf:meta-llama/Llama-3.2-11B-Vision (family); unverified]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    cross_attn_every=5, n_image_tokens=1024,
    rope_theta=500_000.0,
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    cross_attn_every=2, n_image_tokens=8,
)

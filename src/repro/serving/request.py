"""Request / RequestState for the continuous-batching engine.

A `Request` is what a client submits: prompt ids, a generation budget, a
stop token, and per-request `SamplingParams` (greedy / temperature / top-k /
top-p / seed). The engine wraps it in a `RequestState` — queue bookkeeping,
the slot it occupies while running, the streamed token buffer, and
lifecycle timestamps for latency accounting.

Timestamp contract: every latency-bearing stamp (`submit_t`, `admit_t`,
`first_token_t`, `finish_t`, `token_times`) comes from the engine's
injected **monotonic** clock (`time.perf_counter` by default,
`metrics.FakeClock` in tests) — TTFT/TPOT/e2e differences must never see
a wall-clock step. `arrival_t` is the one wall-clock (`time.time`) stamp,
kept so logs can be correlated with the outside world.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode parameters; the engine packs these into the (B,)
    vectors `lm.ragged_decode_step` consumes, so rows with different
    settings share one compiled step."""

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0        # <= 0: full distribution
    top_p: float = 0.0    # outside (0, 1): nucleus filter off
    seed: int = 0         # per-request PRNG stream (greedy ignores it)

    def validate(self) -> None:
        if self.temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {self.temperature}")
        if not 0.0 <= self.top_p <= 1.0:
            raise ValueError(f"top_p must be in [0, 1], got {self.top_p}")


@dataclasses.dataclass(frozen=True)
class Request:
    """What a client submits. Every field is validated here, at submit
    time, with an actionable message — a malformed request must fail on
    the caller's stack, not steps later deep inside admission.

    ``deadline_s`` / ``ttft_deadline_s`` are **relative** budgets in
    seconds on the engine's monotonic clock, measured from ``submit_t``:
    a request past its end-to-end deadline (or still token-less past its
    TTFT deadline) is retired ``TIMED_OUT`` between device steps, and
    deadline-aware admission refuses queued work that can no longer meet
    its TTFT budget instead of wasting prefill on it."""

    prompt: tuple[int, ...]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    priority: int = 0  # lower admits first; FIFO among equals
    deadline_s: Optional[float] = None       # submit -> retire budget
    ttft_deadline_s: Optional[float] = None  # submit -> first token budget

    def __post_init__(self):
        object.__setattr__(self, "prompt", tuple(int(t) for t in self.prompt))
        if not self.prompt:
            raise ValueError(
                "empty prompt: a request must carry at least one token id "
                "(the engine has nothing to prefill)")
        if any(t < 0 for t in self.prompt):
            bad = next(t for t in self.prompt if t < 0)
            raise ValueError(
                f"prompt contains negative token id {bad}: ids must be "
                ">= 0 (negative values are reserved for the engine's "
                "failure sentinel)")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        for name in ("deadline_s", "ttft_deadline_s"):
            d = getattr(self, name)
            if d is None:
                continue
            d = float(d)
            if not math.isfinite(d) or d <= 0:
                raise ValueError(
                    f"{name} must be a finite number of seconds > 0, got "
                    f"{d!r} (omit it — None — for no deadline)")
            object.__setattr__(self, name, d)
        self.sampling.validate()


QUEUED, PREFILLING, RUNNING, FINISHED = \
    "queued", "prefilling", "running", "finished"
#: evicted from its slot mid-flight; waiting in the scheduler queue with a
#: snapshot of its emitted tokens. Re-admission replays them (deterministic
#: re-prefill + re-decode) before new tokens are emitted.
PREEMPTED = "preempted"
#: terminal failure statuses (PR 10 robustness layer): a request past its
#: deadline, cancelled by the client, or whose row produced non-finite
#: logits. All free their slot and pool blocks exactly like FINISHED; the
#: difference is only how the outcome is reported (`finish_reason`,
#: `RequestState.error`, the metrics terminal-reason breakdown).
TIMED_OUT, CANCELLED, FAILED = "timed_out", "cancelled", "failed"

#: every status a request can end in; `RequestState.done` is membership
#: here, and the chaos harness asserts every submitted request reaches one.
TERMINAL_STATUSES = frozenset((FINISHED, TIMED_OUT, CANCELLED, FAILED))


@dataclasses.dataclass
class RequestState:
    request: Request
    request_id: int
    arrival_t: float              # wall clock (time.time), for logs only
    submit_t: float = 0.0         # monotonic; every latency delta below
    status: str = QUEUED          # is computed against this clock
    slot: int = -1
    prefill_pos: int = 0          # chunked prefill frontier
    tokens: list[int] = dataclasses.field(default_factory=list)
    token_times: list[float] = dataclasses.field(default_factory=list)
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    #: "eos" | "length" (FINISHED) | "timeout" | "cancelled" | "failed"
    finish_reason: Optional[str] = None
    #: structured failure payload (status FAILED only): the non-finite
    #: logit guard records the offending engine step, the horizon index
    #: within its block, and how many tokens had streamed before the hit.
    error: Optional[dict] = None
    # -- preemption / resume bookkeeping --------------------------------
    # FIFO stamp from the scheduler's first submit; preserved across
    # requeues so a preempted request re-enters ahead of everything that
    # arrived after it (no starvation by later traffic).
    queue_seq: Optional[int] = None
    preempt_count: int = 0
    # tokens still to be regenerated (not re-emitted) after a resume: the
    # engine re-prefills the original prompt and lets the deterministic
    # decode path re-sample the snapshot; emissions are suppressed until
    # this counter drains, so clients never see a duplicate token.
    replay_left: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.request.prompt)

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    def output(self, *, strip_eos: bool = False) -> list[int]:
        toks = list(self.tokens)
        if (strip_eos and self.finish_reason == "eos" and toks
                and toks[-1] == self.request.eos_id):
            toks = toks[:-1]
        return toks

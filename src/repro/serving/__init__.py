"""Continuous-batching serving engine over the quantized decode fast-path.

`engine.Engine` owns the slot pool (fixed cache rows) and the step loop;
`scheduler.Scheduler` decides who gets a free slot when; `request.Request`
carries per-request sampling parameters and the streamed token buffer;
`paged.BlockPool` replaces contiguous cache rows with block-granular paged
allocation (``Engine(kv_block_size=...)``) so admission is bounded by
actual tokens, not worst-case request length — and
``Engine(overcommit=True)`` drops even the worst-case reservation for
optimistic per-token allocation with preempt-and-requeue (deterministic
replay resume) as the safety valve; `metrics.EngineMetrics` is
the telemetry facade every engine carries (`Engine.metrics.snapshot()` —
TTFT/TPOT/e2e percentiles, occupancy and free-block gauges, backpressure
and horizon-waste counters, host/prefill/device phase timing).

Robustness: requests carry optional deadlines
(``Request(deadline_s=, ttft_deadline_s=)`` → ``TIMED_OUT``), can be
cancelled at any stage (`Engine.cancel` → ``CANCELLED``), and a row whose
logits go non-finite is retired alone as ``FAILED`` while the rest of the
batch continues bitwise-unchanged; a stuck drain raises `EngineStuck`
with a diagnostic dump. `faults.FaultSchedule` injects deterministic
fault schedules (``Engine(fault_hook=...)`` or ``REPRO_FAULTS``) and
`faults.run_chaos` drives the chaos property test over them.
"""

from repro.serving.engine import Engine, EngineStuck
from repro.serving.faults import FaultSchedule, run_chaos
from repro.serving.metrics import EngineMetrics, FakeClock
from repro.serving.paged import BlockPool, PoolExhausted
from repro.serving.request import (
    CANCELLED,
    FAILED,
    FINISHED,
    TERMINAL_STATUSES,
    TIMED_OUT,
    Request,
    RequestState,
    SamplingParams,
)
from repro.serving.scheduler import Scheduler

__all__ = ["BlockPool", "CANCELLED", "Engine", "EngineMetrics",
           "EngineStuck", "FAILED", "FINISHED", "FakeClock",
           "FaultSchedule", "PoolExhausted", "Request", "RequestState",
           "SamplingParams", "Scheduler", "TERMINAL_STATUSES",
           "TIMED_OUT", "run_chaos"]

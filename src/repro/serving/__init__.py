"""Continuous-batching serving engine over the quantized decode fast-path.

`engine.Engine` owns the slot pool (fixed cache rows) and the step loop;
`scheduler.Scheduler` decides who gets a free slot when; `request.Request`
carries per-request sampling parameters and the streamed token buffer.
"""

from repro.serving.engine import Engine
from repro.serving.request import Request, RequestState, SamplingParams
from repro.serving.scheduler import Scheduler

__all__ = ["Engine", "Request", "RequestState", "SamplingParams", "Scheduler"]

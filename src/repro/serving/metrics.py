"""Dependency-free metrics core + the engine's telemetry facade.

Three primitives, stdlib-only (no numpy/jax — importable anywhere, usable
from host-side hot loops without pulling device state):

* `Counter`s — plain monotonic ints, kept in a dict on the facade.
* `Gauge` — last-sampled value plus min/max/mean over the samples (the
  engine samples queue depth, slot occupancy and free-block count once
  per step).
* `Histogram` — fixed log-spaced buckets with percentile *estimation*:
  values land in geometric buckets (default 8 per decade, 1µs..10ks), a
  percentile walks the cumulative counts and interpolates geometrically
  inside its bucket, clamped to the exact observed min/max. Relative
  error is bounded by the bucket growth factor (~33% at 8/decade) and
  memory is O(buckets), never O(samples) — the right trade for an
  always-on serving counter. `percentiles` is the *exact* (sorted,
  linearly interpolated — numpy-default-compatible) helper for offline
  lists; the benchmarks share it instead of carrying their own.

Timestamps are **monotonic** (`time.perf_counter` by default): TTFT/TPOT
math must never see a wall-clock step (NTP slew, suspend). The one
wall-clock stamp kept is `RequestState.arrival_t`, for logs. The clock is
injectable — `FakeClock` makes every latency test deterministic.

`EngineMetrics` is the facade the engine drives through lifecycle hooks
(`on_submit` → `on_admit` → `on_prefill_chunk`* → `on_first_token` →
`on_retire`) plus per-step samples (`sample_step`) and phase timings
(`observe_step`: host vs admission-prefill vs the single compiled decode
call). ``enabled=False`` turns every hook into an early-return no-op —
the engine's outputs are bitwise identical either way (metrics never
touch device code; the zero-interference test pins it).

`snapshot()` returns a **stable plain-dict schema** (see
`SNAPSHOT_SCHEMA`; `check_snapshot` verifies an instance against it so
field renames fail loudly in `run.py --check`). With `REPRO_METRICS_LOG`
set (or `log_path=`), lifecycle events append as JSONL — one object per
line with both wall and monotonic stamps — for offline trace tools.
"""

from __future__ import annotations

import json
import math
import os
import time
from typing import Callable, Optional, Sequence


class FakeClock:
    """Deterministic injectable clock: returns a manually advanced time."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# percentile helpers (exact, shared with the benchmarks)
# ---------------------------------------------------------------------------


def percentiles(values: Sequence[float], ps: Sequence[float]) -> list:
    """Exact percentiles of ``values`` via sort + linear interpolation
    (the numpy default "linear" method, reimplemented so the metrics core
    stays dependency-free). Empty input maps every p to 0.0."""
    if not values:
        return [0.0 for _ in ps]
    s = sorted(float(v) for v in values)
    n = len(s)
    out = []
    for p in ps:
        rank = (float(p) / 100.0) * (n - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, n - 1)
        frac = rank - lo
        out.append(s[lo] + (s[hi] - s[lo]) * frac)
    return out


def pcts_ms(seconds: Sequence[float], ps: Sequence[float] = (50, 99)) -> dict:
    """``{"p50_ms": ..., "p99_ms": ...}`` from a list of second-valued
    latencies — the shape the serving benchmark records."""
    vals = percentiles([v * 1e3 for v in seconds], ps)
    return {f"p{int(p)}_ms": float(v) for p, v in zip(ps, vals)}


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


class Gauge:
    """Last-set value plus min/max/mean over all samples."""

    __slots__ = ("last", "vmin", "vmax", "total", "samples")

    def __init__(self):
        self.last: Optional[float] = None
        self.vmin = math.inf
        self.vmax = -math.inf
        self.total = 0.0
        self.samples = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.last = v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.total += v
        self.samples += 1

    def summary(self) -> dict:
        if not self.samples:
            return {"last": None, "min": None, "max": None, "mean": None,
                    "samples": 0}
        return {"last": self.last, "min": self.vmin, "max": self.vmax,
                "mean": self.total / self.samples, "samples": self.samples}


class Histogram:
    """Log-bucketed histogram over (0, inf) with percentile estimation.

    Bucket i spans ``[lo * g**i, lo * g**(i+1))`` with ``g = 10**(1 /
    buckets_per_decade)``; values below ``lo`` land in bucket 0, values at
    or above ``hi`` in the last bucket. ``percentile`` walks the
    cumulative counts to the target rank and interpolates geometrically
    within the bucket, then clamps to the exact observed [min, max] — so
    p0/p100 are exact and every estimate is within one bucket's growth
    factor of the true order statistic.
    """

    __slots__ = ("lo", "hi", "counts", "n", "total", "vmin", "vmax",
                 "_inv_log_g", "_log_lo", "_g")

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 8):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        decades = math.log10(hi / lo)
        n_buckets = max(1, int(round(decades * buckets_per_decade)))
        self.lo, self.hi = float(lo), float(hi)
        self._g = 10.0 ** (1.0 / buckets_per_decade)
        self._log_lo = math.log(self.lo)
        self._inv_log_g = 1.0 / math.log(self._g)
        self.counts = [0] * n_buckets
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int((math.log(v) - self._log_lo) * self._inv_log_g)
        return min(i, len(self.counts) - 1)

    def bucket_bounds(self, i: int) -> tuple:
        """[lower, upper) edges of bucket ``i``."""
        return (self.lo * self._g ** i, self.lo * self._g ** (i + 1))

    def record(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self.counts[self._index(v)] += 1

    def percentile(self, p: float) -> float:
        if not self.n:
            return 0.0
        target = (float(p) / 100.0) * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= target:
                lo_edge, hi_edge = self.bucket_bounds(i)
                frac = (target - seen) / c
                est = lo_edge * (hi_edge / lo_edge) ** frac
                return min(max(est, self.vmin), self.vmax)
            seen += c
        return self.vmax

    def summary(self, ps: Sequence[float] = (50, 90, 99)) -> dict:
        out = {
            "count": self.n,
            "mean": (self.total / self.n) if self.n else 0.0,
            "min": self.vmin if self.n else 0.0,
            "max": self.vmax if self.n else 0.0,
        }
        for p in ps:
            out[f"p{int(p)}"] = self.percentile(p)
        return out


# ---------------------------------------------------------------------------
# the engine facade
# ---------------------------------------------------------------------------

COUNTER_NAMES = (
    "submitted", "admitted", "finished", "finished_eos", "finished_length",
    "tokens_out", "tokens_finished", "prefill_chunks",
    "blocked_on_slots", "blocked_on_blocks", "blocked_on_budget",
    "horizon_waste_steps", "steps", "device_steps",
    # preemption / overcommit (schema v2): victims evicted, pool blocks
    # their eviction returned to the free list, and recompute waste — the
    # prompt + replay positions a resumed request re-runs before emitting
    # anything new. resume_prefill_tokens is the price overcommit pays for
    # its extra concurrency; read it against tokens_out.
    "preemptions", "blocks_reclaimed", "resume_prefill_tokens",
    # robustness (schema v3): the non-FINISHED terminal outcomes —
    # deadline expiries, client cancels, poisoned-row failures — and steps
    # the wall-clock watchdog flagged as slower than its threshold. With
    # `finished` (eos/length only) these satisfy the conservation identity
    # submitted == finished + timed_out + cancelled + failed + in_flight.
    "timed_out", "cancelled", "failed", "watchdog_slow_steps",
)

_HIST_KEYS = ("count", "mean", "min", "max", "p50", "p90", "p99")
_GAUGE_KEYS = ("last", "min", "max", "mean", "samples")
_PHASE_KEYS = _HIST_KEYS + ("total",)

#: The stable snapshot layout: section -> field -> nested keys (None for
#: scalars). `check_snapshot` verifies an instance against this and the
#: metrics test pins it — rename a field and both fail loudly.
SNAPSHOT_SCHEMA = {
    "schema_version": None,
    "elapsed_s": None,
    "counters": {name: None for name in COUNTER_NAMES},
    "gauges": {name: dict.fromkeys(_GAUGE_KEYS)
               for name in ("queue_depth", "slot_occupancy", "free_blocks")},
    "latency_s": {name: dict.fromkeys(_HIST_KEYS)
                  for name in ("ttft", "tpot", "e2e", "queue_wait")},
    "phase_s": {name: dict.fromkeys(_PHASE_KEYS)
                for name in ("host", "prefill", "device")},
    "throughput": {"tok_s": None, "goodput_tok_s": None},
    # terminal-reason breakdown (schema v3): where every submitted request
    # ended up. in_flight is derived (submitted minus the four terminal
    # counters) so the section always satisfies the conservation identity.
    "terminal": {"finished": None, "timed_out": None, "cancelled": None,
                 "failed": None, "in_flight": None},
}

# v2: + preemptions / blocks_reclaimed / resume_prefill_tokens
# v3: + timed_out / cancelled / failed / watchdog_slow_steps counters and
#     the "terminal" breakdown section (robustness layer)
SCHEMA_VERSION = 3


def check_snapshot(snap: dict) -> list:
    """Structural check of a snapshot against `SNAPSHOT_SCHEMA`. Returns a
    list of human-readable mismatches (empty == conforming) — the
    `run.py --check` schema gate prints and fails on any entry."""
    problems: list[str] = []

    def walk(expected, got, path):
        if expected is None:
            return  # scalar leaf; value type is the producer's business
        if not isinstance(got, dict):
            problems.append(f"{path}: expected a dict, got {type(got).__name__}")
            return
        missing = set(expected) - set(got)
        extra = set(got) - set(expected)
        for k in sorted(missing):
            problems.append(f"{path}.{k}: missing")
        for k in sorted(extra):
            problems.append(f"{path}.{k}: unexpected field")
        for k in sorted(set(expected) & set(got)):
            walk(expected[k], got[k], f"{path}.{k}")

    walk(SNAPSHOT_SCHEMA, snap, "snapshot")
    if not problems and snap.get("schema_version") != SCHEMA_VERSION:
        problems.append(
            f"snapshot.schema_version: expected {SCHEMA_VERSION}, "
            f"got {snap.get('schema_version')!r}")
    return problems


class EngineMetrics:
    """The engine's telemetry facade: lifecycle hooks in, snapshot out.

    All state is host-side python; hooks are no-ops when ``enabled`` is
    False. The engine stamps `RequestState` monotonic timestamps *before*
    calling the hooks, so the facade only derives (it never reads the
    clock mid-request — deriving from stamps keeps TTFT/TPOT/e2e exactly
    consistent with the per-request record a client sees).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 log_path: Optional[str] = None):
        self.enabled = bool(enabled)
        self.clock = clock
        self.counters = dict.fromkeys(COUNTER_NAMES, 0)
        self.gauges = {"queue_depth": Gauge(), "slot_occupancy": Gauge(),
                       "free_blocks": Gauge()}
        self.latency = {"ttft": Histogram(), "tpot": Histogram(),
                        "e2e": Histogram(), "queue_wait": Histogram()}
        self.phase = {"host": Histogram(), "prefill": Histogram(),
                      "device": Histogram()}
        self._t0 = clock() if self.enabled else 0.0
        self._log = None
        if self.enabled:
            if log_path is None:
                log_path = os.environ.get("REPRO_METRICS_LOG") or None
            if log_path:
                self._log = open(log_path, "a")

    # -- counters / events ------------------------------------------------

    def count(self, name: str, n: int = 1) -> None:
        if self.enabled:
            self.counters[name] += n

    def event(self, name: str, **fields) -> None:
        """Append one JSONL record to the event log (no-op without a
        sink). Records carry both stamps: ``t`` monotonic (joinable with
        the snapshot's latency math) and ``t_wall`` for humans."""
        if self._log is None:
            return
        rec = {"t": self.clock(), "t_wall": time.time(), "event": name}
        rec.update(fields)
        self._log.write(json.dumps(rec) + "\n")
        self._log.flush()

    def close(self) -> None:
        if self._log is not None:
            self._log.close()
            self._log = None

    # -- request lifecycle ------------------------------------------------

    def on_submit(self, st) -> None:
        if not self.enabled:
            return
        self.counters["submitted"] += 1
        self.event("submit", request_id=st.request_id,
                   prompt_len=st.prompt_len,
                   max_new_tokens=st.request.max_new_tokens)

    def on_admit(self, st) -> None:
        if not self.enabled:
            return
        self.counters["admitted"] += 1
        wait = st.admit_t - st.submit_t
        self.latency["queue_wait"].record(wait)
        self.event("admit", request_id=st.request_id, slot=st.slot,
                   queue_wait_s=wait)

    def on_prefill_chunk(self, st, start: int, end: int) -> None:
        if not self.enabled:
            return
        self.counters["prefill_chunks"] += 1
        self.event("prefill_chunk", request_id=st.request_id, slot=st.slot,
                   start=start, end=end)

    def on_first_token(self, st) -> None:
        if not self.enabled:
            return
        ttft = st.first_token_t - st.submit_t
        self.latency["ttft"].record(ttft)
        self.event("first_token", request_id=st.request_id, ttft_s=ttft)

    def on_retire(self, st, reason: str, horizon_waste: int) -> None:
        """Any terminal outcome. ``reason`` "eos"/"length" counts as a
        normal finish (tokens_finished feeds goodput); "timeout" /
        "cancelled" / "failed" bump their own terminal counters instead —
        their tokens were emitted but never delivered as a completion, so
        they stay out of goodput by design."""
        if not self.enabled:
            return
        c = self.counters
        if reason in ("eos", "length"):
            c["finished"] += 1
            c[f"finished_{reason}"] += 1
            c["tokens_finished"] += len(st.tokens)
        else:
            c[{"timeout": "timed_out", "cancelled": "cancelled",
               "failed": "failed"}[reason]] += 1
        c["horizon_waste_steps"] += int(horizon_waste)
        e2e = st.finish_t - st.submit_t
        self.latency["e2e"].record(e2e)
        if len(st.tokens) > 1 and st.first_token_t is not None:
            self.latency["tpot"].record(
                (st.finish_t - st.first_token_t) / (len(st.tokens) - 1))
        self.event("retire", request_id=st.request_id, reason=reason,
                   n_tokens=len(st.tokens), e2e_s=e2e,
                   horizon_waste_steps=int(horizon_waste))

    def on_preempt(self, st, blocks_reclaimed: int) -> None:
        """A running/prefilling request was evicted from its slot: its
        pool blocks went back to the free list and it was re-queued with
        its original priority and arrival order."""
        if not self.enabled:
            return
        self.counters["preemptions"] += 1
        self.counters["blocks_reclaimed"] += int(blocks_reclaimed)
        self.event("preempt", request_id=st.request_id, slot=st.slot,
                   n_tokens=len(st.tokens), preempt_count=st.preempt_count,
                   blocks_reclaimed=int(blocks_reclaimed))

    def on_resume(self, st, recompute_tokens: int) -> None:
        """A preempted request was re-admitted; ``recompute_tokens`` is
        the prompt re-prefill + token replay work it must redo before any
        new token reaches the client (overcommit's recompute waste)."""
        if not self.enabled:
            return
        self.counters["resume_prefill_tokens"] += int(recompute_tokens)
        self.event("resume", request_id=st.request_id, slot=st.slot,
                   recompute_tokens=int(recompute_tokens),
                   preempt_count=st.preempt_count)

    def on_blocked(self, kind: str) -> None:
        """One per engine step spent with queued work that could not be
        admitted: ``kind`` in slots / blocks / budget."""
        self.count(f"blocked_on_{kind}")

    # -- per-step samples -------------------------------------------------

    def sample_step(self, *, queue_depth: int, running: int, n_slots: int,
                    free_blocks: Optional[int]) -> None:
        if not self.enabled:
            return
        self.gauges["queue_depth"].set(queue_depth)
        self.gauges["slot_occupancy"].set(running / max(n_slots, 1))
        if free_blocks is not None:
            self.gauges["free_blocks"].set(free_blocks)

    def observe_step(self, *, host_s: float, prefill_s: float = 0.0,
                     device_s: Optional[float] = None) -> None:
        """Phase timing for one engine step: ``device_s`` is the single
        compiled decode call (transfer included — that is where the step
        blocks), ``prefill_s`` the admission/chunk compiled calls, and
        ``host_s`` everything else (scheduling, bookkeeping, uploads)."""
        if not self.enabled:
            return
        self.phase["host"].record(host_s)
        if prefill_s > 0.0:
            self.phase["prefill"].record(prefill_s)
        if device_s is not None:
            self.phase["device"].record(device_s)

    # -- export -----------------------------------------------------------

    def snapshot(self) -> dict:
        """The stable plain-dict export (see `SNAPSHOT_SCHEMA`)."""
        elapsed = max(self.clock() - self._t0, 0.0) if self.enabled else 0.0
        denom = max(elapsed, 1e-9)
        c = self.counters
        terminal = {k: c[k] for k in
                    ("finished", "timed_out", "cancelled", "failed")}
        terminal["in_flight"] = c["submitted"] - sum(terminal.values())
        return {
            "schema_version": SCHEMA_VERSION,
            "elapsed_s": elapsed,
            "counters": dict(self.counters),
            "gauges": {k: g.summary() for k, g in self.gauges.items()},
            "latency_s": {k: h.summary() for k, h in self.latency.items()},
            "phase_s": {k: dict(h.summary(), total=h.total)
                        for k, h in self.phase.items()},
            "throughput": {
                "tok_s": self.counters["tokens_out"] / denom,
                "goodput_tok_s": self.counters["tokens_finished"] / denom,
            },
            "terminal": terminal,
        }

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.snapshot(), **dump_kw)

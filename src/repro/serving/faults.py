"""Deterministic fault injection for the serving engine (chaos harness).

A `FaultSchedule` is a seeded per-step hook (``Engine(fault_hook=...)``,
called once per step between bookkeeping and admission) that rolls an
independent chance for each fault class and injects through the engine's
*public* fault surface — never by mutating internals a real failure could
not reach:

* **exhaust** — arms one synthetic `PoolExhausted` at the next block
  demand (overcommit engines only: that is the mode where exhaustion is a
  recoverable event). The fault flows through the genuine preemption
  machinery, evicting a real victim.
* **nan** — `Engine.inject_nan` on a random RUNNING slot: the next device
  step's logits for that row are NaN, the guard emits the FAILED
  sentinel, and the host retires exactly that request as ``FAILED``.
* **clock** — jumps the injected `FakeClock` forward (deadline expiries,
  watchdog slow-step hits). Requires ``clock=``; never available via
  ``REPRO_FAULTS`` (a real clock cannot be jumped).
* **storm** — submits a burst of ``storm_size`` requests from
  ``request_factory(rng)`` mid-run (admission backpressure under load).
  The injected states are recorded in ``schedule.injected`` so the chaos
  test can hold them to the all-terminal invariant too.
* **cancel** — cancels a uniformly random live request (any stage).

The draw sequence is a pure function of the seed — every fault, victim
and burst replays bit-for-bit — and ``schedule.log`` keeps an audit trail
(one record per injected fault, with the engine step it landed on).

`run_chaos` is the property-test driver shared by
``tests/test_serving_faults.py`` and the ``serving_fault_chaos`` gate in
``run.py --check``: submit, drain under the schedule, audit
`BlockPool.check` after every step, and require every request (original
and storm-injected) to reach a terminal state plus the metrics terminal
conservation identity.

``REPRO_FAULTS`` installs a schedule on any engine without code changes:
a comma-separated spec like ``seed=3,nan=0.05,exhaust=0.1,cancel=0.02``
(see `FaultSchedule.from_spec`).
"""

from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.serving.request import RUNNING

#: REPRO_FAULTS spec keys -> (constructor kwarg, parser). Clock jumps and
#: submit storms need injected collaborators (a FakeClock, a request
#: factory) and are deliberately absent — an env var cannot supply them.
_SPEC_KEYS = {
    "seed": ("seed", int),
    "nan": ("nan_rate", float),
    "exhaust": ("exhaust_rate", float),
    "cancel": ("cancel_rate", float),
    "max_faults": ("max_faults", int),
}


class FaultSchedule:
    """Seeded per-step fault injector (see module docstring). Rates are
    independent per-step probabilities in [0, 1]; a step can land several
    fault classes at once. ``max_faults`` caps the total injected (the
    schedule goes quiet after), so a chaos run always drains."""

    def __init__(self, seed: int = 0, *,
                 nan_rate: float = 0.0,
                 exhaust_rate: float = 0.0,
                 clock_rate: float = 0.0,
                 clock_jump_s: float = 10.0,
                 storm_rate: float = 0.0,
                 storm_size: int = 4,
                 cancel_rate: float = 0.0,
                 max_faults: Optional[int] = None,
                 request_factory: Optional[Callable] = None,
                 clock=None):
        for name, rate in (("nan_rate", nan_rate),
                           ("exhaust_rate", exhaust_rate),
                           ("clock_rate", clock_rate),
                           ("storm_rate", storm_rate),
                           ("cancel_rate", cancel_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        if clock_rate > 0 and clock is None:
            raise ValueError("clock_rate needs an injectable clock "
                             "(pass clock=FakeClock instance)")
        if storm_rate > 0 and request_factory is None:
            raise ValueError("storm_rate needs request_factory "
                             "(rng -> Request)")
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.nan_rate = nan_rate
        self.exhaust_rate = exhaust_rate
        self.clock_rate = clock_rate
        self.clock_jump_s = float(clock_jump_s)
        self.storm_rate = storm_rate
        self.storm_size = int(storm_size)
        self.cancel_rate = cancel_rate
        self.max_faults = max_faults
        self.request_factory = request_factory
        self.clock = clock
        # audit trail + affected-request bookkeeping for the chaos test's
        # unaffected-requests-bitwise-equal oracle comparison
        self.log: List[dict] = []
        self.injected: List = []        # storm-submitted RequestStates
        self.poisoned: set = set()      # request_ids hit by inject_nan
        self.cancelled: set = set()     # request_ids cancelled by us
        self.n_faults = 0

    @classmethod
    def from_spec(cls, spec: str) -> "FaultSchedule":
        """Parse a ``REPRO_FAULTS`` spec: comma-separated ``key=value``
        with keys seed / nan / exhaust / cancel / max_faults. Unknown
        keys raise (a typo must not silently disable the fault)."""
        kw = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            key, sep, val = item.partition("=")
            if not sep or key not in _SPEC_KEYS:
                raise ValueError(
                    f"bad REPRO_FAULTS entry {item!r}: expected key=value "
                    f"with key in {sorted(_SPEC_KEYS)}")
            name, parse = _SPEC_KEYS[key]
            kw[name] = parse(val)
        return cls(kw.pop("seed", 0), **kw)

    def _record(self, kind: str, step: int, **fields) -> None:
        rec = {"kind": kind, "step": step}
        rec.update(fields)
        self.log.append(rec)
        self.n_faults += 1

    def __call__(self, engine) -> None:
        """The per-step hook. Draws are consumed every step (even quiet
        ones) so the fault sequence is a pure function of the seed, not
        of which faults happened to be eligible."""
        draws = self.rng.random(5)
        if self.max_faults is not None and self.n_faults >= self.max_faults:
            return
        step = engine.stats["steps"]
        if draws[0] < self.exhaust_rate and engine.overcommit:
            engine._fault_exhaust_once = True
            self._record("exhaust", step)
        if draws[1] < self.nan_rate:
            slots = [i for i, s in enumerate(engine._slots)
                     if s is not None and s.status == RUNNING]
            if slots:
                slot = slots[int(self.rng.integers(len(slots)))]
                self.poisoned.add(engine._slots[slot].request_id)
                engine.inject_nan(slot)
                self._record("nan", step, slot=slot,
                             request_id=engine._slots[slot].request_id)
        if draws[2] < self.clock_rate:
            self.clock.advance(self.clock_jump_s)
            self._record("clock_jump", step, jump_s=self.clock_jump_s)
        if draws[3] < self.storm_rate:
            burst = [engine.submit(self.request_factory(self.rng))
                     for _ in range(self.storm_size)]
            self.injected.extend(burst)
            self._record("storm", step, n=len(burst),
                         request_ids=[st.request_id for st in burst])
        if draws[4] < self.cancel_rate:
            live = engine.live_states()
            if live:
                victim = live[int(self.rng.integers(len(live)))]
                if engine.cancel(victim.request_id):
                    self.cancelled.add(victim.request_id)
                    self._record("cancel", step,
                                 request_id=victim.request_id)


def run_chaos(engine, requests, schedule: FaultSchedule, *,
              max_steps: int = 5000) -> dict:
    """Drive ``engine`` through ``requests`` under ``schedule``, auditing
    the robustness invariants after every step. Returns ``{"states",
    "violations", "steps"}`` — states covers originals *and* the
    schedule's storm-injected requests; an empty violations list is the
    chaos property. Shared by the pytest chaos test and the
    ``serving_fault_chaos`` gate, so CI and the test suite judge the
    same contract."""
    states = [engine.submit(r) for r in requests]
    violations: List[str] = []
    steps = 0
    while engine.has_work() and steps < max_steps:
        engine.step()
        steps += 1
        if engine.pool is not None:
            for problem in engine.pool.check():
                violations.append(f"step {steps}: pool: {problem}")
    all_states = states + list(schedule.injected)
    for st in all_states:
        if not st.done:
            violations.append(
                f"request {st.request_id} never reached a terminal "
                f"state: {st.status} after {steps} steps")
    snap = engine.metrics.snapshot()
    term = snap["terminal"]
    if engine.metrics.enabled:
        if term["in_flight"] != 0:
            violations.append(
                f"terminal conservation violated: in_flight="
                f"{term['in_flight']} after drain ({term})")
        if snap["counters"]["submitted"] != len(all_states):
            violations.append(
                f"submitted counter {snap['counters']['submitted']} != "
                f"{len(all_states)} requests the harness knows about")
    return {"states": all_states, "violations": violations, "steps": steps}

"""Paged (block-granular) allocation for the engine's int8 KV cache.

The slot-row cache reserves ``max_len`` positions per request for its whole
lifetime, so admission capacity is bounded by the *worst-case* request
length: a 14-token request strands the other ``max_len - 14`` positions of
its row. ABQ's 2.7x KV compression only turns into real concurrency if the
runtime can pack that freed memory — which is what this module does, the
vLLM idea restricted to what the repo's no-preemption engine can keep
sound:

* The device cache is a **pool** of ``n_blocks`` physical blocks of
  ``block_size`` tokens each (per layer, per KV head — the same
  attention-native int8 values + f32 per-token scales as the slot rows,
  just chopped on the sequence axis). Leaf layout:
  ``(L, n_blocks + 1, KVH, block_size, D)`` — physical block 0 is the
  TRASH block (see below), ids ``1..n_blocks`` are allocatable.
* Each slot owns a **block table**: a ``(max_blocks,)`` row mapping
  logical block index (``pos // block_size``) to physical block id.
  Unmapped entries point at TRASH. The table lives host-side here and is
  mirrored to the device as one small int32 array; every KV read/write in
  the decode step resolves through it (gather/scatter indirection in
  `attention.attend_decode`, scalar-prefetched index maps in the Pallas
  kernel's paged mode).
* **Free-list allocation, alloc-on-demand**: physical blocks are taken
  from the free list only when a slot's write frontier crosses into an
  unmapped logical block (at admission for the prefill extent, then one
  block at a time as decode advances). Retirement returns every held
  block to the free list in the same host step, so a short request's
  memory is reusable the moment it finishes — internal fragmentation is
  bounded by one partial block per live request.
* **Two admission regimes.** Conservative (default): admission reserves
  the request's worst-case block count (prompt extent + generation budget
  + horizon headroom) and the free list can never be exhausted by a
  within-reservation demand (``sum(allocated) <= sum(reserved) <=
  n_blocks``) — deadlock-free without preemption. Optimistic
  (``optimistic=True``, the engine's ``overcommit`` mode): no
  reservations; blocks are taken strictly on demand and ``ensure`` raises
  `PoolExhausted` when the free list runs dry, which the engine treats as
  a preemption trigger (evict a victim, reclaim its blocks, retry) rather
  than an error. Either way ``sum(allocated) <= n_blocks`` and no block
  is ever mapped by two live slots — invariants pinned by
  ``tests/test_pool_properties.py``.

The TRASH block absorbs the compiled step's frozen-row writes: free,
retired and queued slots still flow through the one compiled decode step
(one specialization serves every occupancy) and their discarded KV write
must land *somewhere*. With slot rows, "somewhere" was the row they owned;
with a shared pool it must never be another request's block — so inactive
slots' tables point every entry at TRASH, whose contents nothing ever
attends (an active row's per-row ``length`` only reaches mapped blocks).
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.configs import ArchConfig

#: physical block id every unmapped table entry points at; never allocated.
TRASH = 0


class PoolExhausted(RuntimeError):
    """Optimistic-mode ``ensure`` found the free list too shallow.

    Raised *before* any block is taken (the failed demand is atomic), so
    the caller can preempt a victim and retry with accounting intact."""


class BlockPool:
    """Fixed pool of ``block_size``-token KV blocks + per-slot block tables.

    Host-side bookkeeping only — the device arrays are built by
    `init_paged_cache` and scattered into by the engine; the pool decides
    *which* physical block a logical position maps to.
    """

    def __init__(self, n_blocks: int, block_size: int, *, n_slots: int,
                 max_blocks: int, optimistic: bool = False):
        if n_blocks < 1:
            raise ValueError(f"need at least one block, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks          # allocatable (excludes TRASH)
        self.block_size = block_size
        self.n_slots = n_slots
        self.max_blocks = max_blocks      # table width = max_len // block_size
        # optimistic: no worst-case reservations; ensure() raises
        # PoolExhausted (a preemption trigger) instead of relying on
        # reservation accounting to make failure impossible.
        self.optimistic = optimistic
        self.alloc_failures = 0           # PoolExhausted raises, lifetime
        # physical ids are 1..n_blocks; 0 is TRASH. LIFO free list, seeded
        # so the first pop hands out block 1.
        self._free: List[int] = list(range(n_blocks, 0, -1))
        self._held: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved = np.zeros(n_slots, np.int64)
        self.table = np.full((n_slots, max_blocks), TRASH, np.int32)
        self.peak_used = 0
        # low watermark of the free list over the pool's lifetime — the
        # operator's "how close did we run to exhaustion" gauge (0 means
        # admission backpressure actually engaged at some point)
        self.min_free = n_blocks

    # -- capacity queries ------------------------------------------------

    @property
    def n_phys(self) -> int:
        """Physical rows in the device pool arrays (incl. TRASH)."""
        return self.n_blocks + 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return int(self._reserved.sum())

    def blocks_for(self, tokens: int) -> int:
        """Blocks covering ``tokens`` cache positions."""
        return -(-int(tokens) // self.block_size)

    def can_reserve(self, n: int) -> bool:
        """Would a worst-case reservation of ``n`` blocks fit right now?"""
        return n <= self.n_blocks - self.reserved_blocks

    def can_alloc(self, n: int) -> bool:
        """Are ``n`` blocks free right now? (optimistic admission gate —
        no forward-looking guarantee, unlike `can_reserve`)."""
        return n <= len(self._free)

    def held(self, slot: int) -> List[int]:
        return list(self._held[slot])

    # -- lifecycle -------------------------------------------------------

    def reserve(self, slot: int, n: int) -> None:
        """Reserve ``n`` blocks worst-case for ``slot`` (at admission).

        Conservative mode only — an optimistic pool allocates purely on
        demand and never reserves."""
        if self.optimistic:
            raise RuntimeError("reserve() is meaningless on an optimistic "
                               "pool — admission gates on can_alloc")
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if n > self.max_blocks:
            raise ValueError(
                f"reservation of {n} blocks exceeds the per-request table "
                f"width ({self.max_blocks})")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"pool exhausted: {n} blocks requested, "
                f"{self.n_blocks - self.reserved_blocks} unreserved "
                "(admission should have gated on can_reserve)")
        self._reserved[slot] = n

    def ensure(self, slot: int, n_logical: int) -> bool:
        """Map logical blocks ``0 .. n_logical-1`` of ``slot``, allocating
        from the free list on demand. Returns True if the table changed
        (the engine re-uploads the device mirror). Conservative mode:
        within-reservation demands can never fail (``sum(allocated) <=
        sum(reserved) <= n_blocks`` keeps the free list deep enough).
        Optimistic mode: raises `PoolExhausted` — atomically, taking no
        blocks — when the free list can't cover the demand."""
        held = self._held[slot]
        if n_logical <= len(held):
            return False
        if self.optimistic:
            if n_logical > self.max_blocks:
                raise ValueError(
                    f"slot {slot} needs {n_logical} blocks, table width is "
                    f"{self.max_blocks}")
            if n_logical - len(held) > len(self._free):
                self.alloc_failures += 1
                self.min_free = min(self.min_free, len(self._free))
                raise PoolExhausted(
                    f"slot {slot} needs {n_logical - len(held)} more blocks, "
                    f"{len(self._free)} free — preempt to reclaim")
        elif n_logical > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} needs {n_logical} blocks but reserved only "
                f"{int(self._reserved[slot])} — reservation accounting bug")
        for i in range(len(held), n_logical):
            blk = self._free.pop()
            held.append(blk)
            self.table[slot, i] = blk
        self.peak_used = max(self.peak_used, self.used_blocks)
        self.min_free = min(self.min_free, len(self._free))
        return True

    def release(self, slot: int) -> int:
        """Free every block ``slot`` holds and drop its reservation (at
        retirement or preemption). The table row snaps back to TRASH so
        the row's frozen garbage write can never land in a reused block.
        Returns the number of blocks reclaimed. Safe to call on a slot
        that holds nothing; each block is held by exactly one slot, so a
        release can never free another request's memory."""
        freed = len(self._held[slot])
        self._free.extend(reversed(self._held[slot]))
        self._held[slot] = []
        self._reserved[slot] = 0
        self.table[slot, :] = TRASH
        return freed

    def check(self) -> List[str]:
        """Audit the pool's invariants; returns human-readable violation
        strings (empty == sound). The chaos harness calls this after every
        engine step — any fault interleaving that corrupts accounting
        (double-free, leaked block, stale table entry) fails loudly here
        instead of surfacing steps later as cross-request KV corruption.

        Invariants: free + held partition {1..n_blocks} exactly (no block
        lost, duplicated, or owned twice); TRASH is never free or held;
        each table row maps exactly its held blocks in order, TRASH after;
        conservative mode never holds beyond its reservation."""
        problems: List[str] = []
        free = list(self._free)
        held_all = [b for held in self._held for b in held]
        for name, ids in (("free list", free), ("held lists", held_all)):
            if TRASH in ids:
                problems.append(f"TRASH block in {name}")
        combined = sorted(free + held_all)
        expected = list(range(1, self.n_blocks + 1))
        if combined != expected:
            from collections import Counter
            c = Counter(free + held_all)
            dupes = sorted(b for b, n in c.items() if n > 1)
            lost = sorted(set(expected) - set(c))
            ghost = sorted(set(c) - set(expected) - {TRASH})
            if dupes:
                problems.append(f"blocks owned twice: {dupes}")
            if lost:
                problems.append(f"blocks lost (neither free nor held): {lost}")
            if ghost:
                problems.append(f"unknown block ids in circulation: {ghost}")
        for slot in range(self.n_slots):
            held = self._held[slot]
            row = self.table[slot]
            if list(row[:len(held)]) != held:
                problems.append(
                    f"slot {slot}: table prefix {list(row[:len(held)])} != "
                    f"held {held}")
            if any(int(b) != TRASH for b in row[len(held):]):
                problems.append(
                    f"slot {slot}: non-TRASH table entries past its "
                    f"{len(held)} held blocks")
            if not self.optimistic and len(held) > self._reserved[slot]:
                problems.append(
                    f"slot {slot}: holds {len(held)} blocks over its "
                    f"reservation of {int(self._reserved[slot])}")
        if not self.optimistic and self.reserved_blocks > self.n_blocks:
            problems.append(
                f"reservations ({self.reserved_blocks}) exceed the pool "
                f"({self.n_blocks})")
        return problems

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "free_blocks": self.free_blocks,
            "used_blocks": self.used_blocks,
            "reserved_blocks": self.reserved_blocks,
            "peak_used_blocks": self.peak_used,
            "min_free_blocks": self.min_free,
            "optimistic": self.optimistic,
            "alloc_failures": self.alloc_failures,
        }


def init_paged_cache(cfg: ArchConfig, pool: BlockPool) -> dict:
    """Device pool arrays: the slot-row cache layout with the batch axis
    replaced by physical blocks and the sequence axis by ``block_size``
    (leaves ``(L, n_phys, KVH, block_size, D)`` int8 values /
    ``(L, n_phys, KVH, block_size)`` f32 scales)."""
    from repro.models import attention as attn_mod

    return {"attn": attn_mod.init_kv_cache(cfg, pool.n_phys,
                                           pool.block_size)}

"""Admission / retirement policy for the continuous-batching engine.

The scheduler owns the waiting queue (a priority heap; FIFO among equal
priorities) and two decisions:

* **admission** — which queued requests get the free slots this step,
  under a per-step prefill-token budget (``max_prefill_tokens``): prefill
  work happens between decode steps, so unbounded admission of long
  prompts would stall every running request. With chunked prefill the
  budget counts one chunk per admitted request; without it, the whole
  prompt. At least one request is always admitted when a slot is free —
  a prompt larger than the whole budget can never be split smaller than
  the policy allows, and deferring it forever would starve it.

* **retirement** — whether a just-emitted token finishes its request
  (stop token, or the max-new-tokens budget); the engine frees the slot
  in the same step, so a queued request can be admitted into it before
  the next device step ("immediate slot reuse").
"""

from __future__ import annotations

import heapq
import itertools
from typing import Optional

from repro.serving.request import RequestState


class Scheduler:
    def __init__(self, *, max_prefill_tokens: Optional[int] = None):
        self._heap: list[tuple[int, int, RequestState]] = []
        self._seq = itertools.count()
        self.max_prefill_tokens = max_prefill_tokens
        # why the last pop_admissions stopped with work still queued:
        # "resource" (can_admit refused the head — in paged mode, no free
        # blocks), "budget" (prefill-token budget spent), or None (free
        # slots ran out / queue drained). The engine's metrics layer turns
        # this into the blocked_on_{blocks,budget} backpressure counters.
        self.last_refusal: Optional[str] = None

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, state: RequestState) -> None:
        """Queue ``state``. The FIFO stamp (``queue_seq``) is assigned once
        on first submit and *preserved* on later submits, so a preempted
        request re-enters ahead of everything that arrived after it."""
        if state.queue_seq is None:
            state.queue_seq = next(self._seq)
        heapq.heappush(self._heap,
                       (state.request.priority, state.queue_seq, state))

    def requeue(self, state: RequestState) -> None:
        """Re-queue a preempted request at its original (priority, arrival)
        position. Together with the engine's victim policy (youngest,
        lowest-priority first, and a per-request preemption-count bound)
        this keeps preemption starvation-free: a victim can only be pushed
        behind requests that were already ahead of it."""
        self.submit(state)

    def states(self) -> list[RequestState]:
        """Every queued state (heap order, not admission order) — the
        engine's deadline sweep walks this to expire waiting requests."""
        return [s for _, _, s in self._heap]

    def remove(self, state: RequestState) -> bool:
        """Drop ``state`` from the queue (cancellation / deadline expiry
        of work that never got a slot). Identity match, O(n) + re-heapify;
        returns False if it was not queued."""
        for i, entry in enumerate(self._heap):
            if entry[2] is state:
                last = self._heap.pop()
                if i < len(self._heap):
                    self._heap[i] = last
                    heapq.heapify(self._heap)
                return True
        return False

    def pop_admissions(self, n_free: int,
                       chunk: Optional[int] = None,
                       can_admit=None) -> list[RequestState]:
        """Pop up to ``n_free`` requests for this step's free slots.

        ``chunk`` is the engine's prefill-chunk size (None: whole-prompt
        prefill); the first prefill installment of each admitted request is
        charged against ``max_prefill_tokens``.

        ``can_admit`` (RequestState -> bool) is the engine's resource gate
        — in paged-KV mode, "does the pool have this request's worst-case
        blocks free". Unlike the prefill budget it also applies to the
        head of the queue (an exhausted pool admits nobody), and it never
        reorders past a refused head: skipping ahead to smaller requests
        would starve the big one behind a stream of shorts."""
        admitted: list[RequestState] = []
        budget = self.max_prefill_tokens
        spent = 0
        self.last_refusal = None
        while self._heap and len(admitted) < n_free:
            _, _, state = self._heap[0]
            if can_admit is not None and not can_admit(state):
                # resource backpressure: stays queued, FIFO-faithful
                self.last_refusal = "resource"
                break
            cost = state.prompt_len if chunk is None \
                else min(state.prompt_len, chunk)
            if admitted and budget is not None and spent + cost > budget:
                # later steps pick it up; never defer the first
                self.last_refusal = "budget"
                break
            heapq.heappop(self._heap)
            spent += cost
            admitted.append(state)
            # a refusal verdict only describes the *current* head — once a
            # request is admitted past it, any earlier reason is stale and
            # must not leak into this step's backpressure attribution.
            self.last_refusal = None
        return admitted

    @staticmethod
    def finish_reason(state: RequestState) -> Optional[str]:
        """Called right after a token lands in ``state.tokens``."""
        req = state.request
        if req.eos_id is not None and state.tokens \
                and state.tokens[-1] == req.eos_id:
            return "eos"
        if len(state.tokens) >= req.max_new_tokens:
            return "length"
        return None

"""Continuous-batching engine over the quantized decode fast-path.

The slot/cache contract
-----------------------

The engine owns a fixed pool of ``n_slots`` cache rows — the batch axis of
the decode cache (`lm.init_cache` layout: attention leaves are
``(L, B, KVH, max_len, D)``, batch-major and pos-indexed). A **slot** is one
row of that pool plus its entries in the per-row state vectors (position,
active flag, sampling parameters). The contract:

* A request owns its slot exclusively from admission to retirement; all of
  its device state lives in that row (prefix KV at positions
  ``0 .. pos-1``) and in the engine's ``(B, 1)`` current-token array.
* Rows are independent: every decode-step op is batch-elementwise or
  batch-contracted (quantized GEMMs are integer-exact per row, attention /
  norms reduce within a row), so a request's tokens are bitwise identical
  whatever the other slots hold. That is what the sequential-oracle test
  pins, and why admission never needs to quiesce the batch.
* ``pos`` is per-row; per-row ``length = pos + 1`` drives the
  decode-attention kernel's S-block skip, so a freshly admitted short
  request does not pay for a long neighbor's prefix (ragged batches are
  free in the kernel).
* Free/retired/prefilling rows still flow through the compiled step (one
  specialization serves every occupancy) but are frozen: ``active=False``
  passes their token and position through, and their (discarded) KV write
  lands at the frozen position — never attended, overwritten on reuse
  (a chunk-prefilling row's ``pos`` is pinned to its prefill frontier so
  the garbage write always falls in the next chunk's span, which the next
  chunk overwrites before anything can attend it).
* Retirement frees the slot in the same host step that observed the
  finishing token; admission runs before the next device call, so a slot
  never idles while work is queued.

The step loop makes exactly ONE device→host transfer per step — the
``(H, B, 1)`` stacked-token result of that step's device call
(``H = step_horizon``). Everything else stays on device: admission prefill,
the decode scan, sampling, and the per-row state vectors themselves (the
device copies are refreshed from the host mirrors only when a slot event
changes them; ``pos``/``step`` advance on device inside the call and the
mirrors replay the update host-side, so a steady-state step uploads
nothing).

``step_horizon`` trades scheduling granularity for dispatch amortization
(multi-step scheduling): each engine step decodes H tokens per row in one
jitted ``lax.scan`` before the host looks again. Retirement then happens at
block granularity — a row that finishes mid-block wastes at most H-1 slot
steps — and a request's *emitted* tokens are bitwise independent of H (the
per-row PRNG is indexed by sample count, not by engine step). H=1 is exact
streaming; throughput-oriented serving wants H≈4-8.

Prefill on admission runs right-padded to ``prefill_bucket`` to bound jit
specializations; the true per-row last-token index picks the first-token
logits (exact under causality). Same-bucket admissions landing on the same
step are batched into ONE compiled prefill+install call. With
``prefill_chunk`` set, prompts longer than one chunk are fed one chunk per
engine step (`lm.prefill_chunk`), so a long prompt never stalls running
decodes for more than a chunk's worth of work; chunked rows attend over
their own already-quantized prefix — decode numerics, not one-shot-prefill
numerics. Each chunk's attention cost is O(prefix), not O(max_len): the
prefix-clamped Pallas kernel (`kernels/chunk_attn.py`) skips S-blocks past
the chunk frontier on TPU, and off-TPU the XLA fallback slices the cache
to a static power-of-two **prefix bucket** (at most log2(max_len) jit
specializations, see `_prefix_bucket`). Chunked prefill composes with
paged KV: the chunk's blocks are pre-mapped before the compiled call and
its writes/reads resolve through the slot's block table.

Paged KV allocation (``kv_block_size``)
---------------------------------------

By default every slot owns a contiguous ``max_len``-position cache row, so
admission capacity is bounded by worst-case request length (a short
request strands the tail of its row). ``kv_block_size=B`` switches the
attention cache to **paged** allocation (`repro.serving.paged.BlockPool`,
dense/moe only): the device cache becomes a pool of B-token physical
blocks plus matching per-scale pages, each slot maps logical positions
through a block table, and blocks are taken from a free list on demand —
ceil(prefill_extent / B) at admission, then one at a time as the decode
frontier crosses a block boundary — and all returned at retirement.
Admission then gates on **free-block count, not free-slot count**: a
request reserves only its own worst-case blocks (prompt + generation
budget + horizon headroom), so under the same cache byte budget the pool
admits strictly more concurrent short requests than ``pool_tokens /
max_len`` slot rows would (run ``n_slots`` higher than the slot-row
equivalent to expose the extra concurrency; `bench_serving` gates the
win). The decode step is unchanged except for the table indirection —
paged greedy decode is bitwise identical to the slot-row path whenever
both run the same attention tile partition (always true of the jnp paths
the tests pin; a TPU run whose tuner picks different block_s for pool
pages vs contiguous rows is numerically, not bitwise, equivalent) — and
the one-transfer-per-step discipline holds: block tables are
tiny int32 host→device uploads on block events, and the step's single
device→host transfer is still the stacked-token block. By default the
worst-case reservation keeps admission deadlock-free without preemption;
``overcommit=True`` replaces it with optimistic allocation (below).

Preemption & optimistic overcommit (``overcommit=True``, paged only)
--------------------------------------------------------------------

Worst-case reservation prices every request at its *budget* (prompt +
``max_new_tokens``), but heavy-tailed traffic mostly stops early — the
reserved tail is dead capacity. ``overcommit=True`` switches the pool to
optimistic mode: admission gates only on the blocks the prefill extent
needs *right now*, blocks are allocated strictly on demand, and when the
free list runs dry at a decode or chunk frontier (`PoolExhausted`), the
engine **preempts** a victim instead of failing:

* **Victim policy**: the lowest-priority, youngest-arrival occupied slot
  (RUNNING or PREFILLING). The highest-priority oldest occupied row is
  *protected* — never chosen — so some row always runs to completion
  (no deadlock). Rows that already hit ``preempt_limit`` evictions are
  passed over while any other candidate exists (bounded per-request
  preemption, no starvation); the demanding row itself is a legal victim
  (it simply re-queues and the step goes on without it).
* **Eviction** releases every pool block the victim holds back to the
  free list in the same host step (each block is held by exactly one
  slot, so this can never free another request's memory), snapshots its
  emitted tokens, and re-queues it at its **original** (priority,
  arrival) position — preemption never demotes a request behind later
  traffic.
* **Resume is deterministic replay, not re-prefill of the generated
  prefix.** The generated tokens' KV was written through the quantized
  decode path; re-prefilling them would re-quantize prefill-regime
  hidden states and can diverge (measurably — see
  ``tests/test_serving_engine.py``). Instead, re-admission re-prefills
  the *original prompt* — bitwise the same computation as the first
  admission — and lets the ordinary decode path regenerate the snapshot:
  the per-request PRNG is indexed by sample count starting at 0 again,
  so every replayed sample sees identical logits and keys and the row
  re-derives its own history exactly. The host suppresses emission until
  the replay drains (``RequestState.replay_left``), so clients never see
  a duplicate or altered token and the resumed stream is bitwise
  identical to an uninterrupted run. The cost is recompute
  (prompt + snapshot re-decoded), surfaced as the
  ``resume_prefill_tokens`` counter against the concurrency overcommit
  buys (``bench_serving`` gates the trade ≥ 1.3x).

Failure handling (deadlines, cancellation, isolation)
-----------------------------------------------------

Production traffic fails per-request, and so does this engine — the only
process-level failure left is a genuinely stuck engine, which raises
`EngineStuck` with a diagnostic dump instead of a bare error:

* **Deadlines**: ``Request(deadline_s=, ttft_deadline_s=)`` are relative
  budgets on the engine's monotonic clock. A sweep between device steps
  (active only while any live request carries a deadline) retires expired
  requests — queued, preempted, or slotted — as ``TIMED_OUT``, freeing
  their slot and pool blocks like any retirement. Deadline-aware
  admission also expires queued work that can no longer meet its TTFT
  budget (estimated from an EWMA of recent step wall time) rather than
  wasting prefill on a request whose client has already given up.
* **Cancellation**: `Engine.cancel(request_id)` retires a request as
  ``CANCELLED`` at any lifecycle stage — queued and preempted states are
  pulled from the scheduler heap, prefilling/running states release their
  slot — and is safe between steps or from a fault hook (stale pending
  bookkeeping for a just-cancelled row is skipped, never applied).
* **Failure isolation**: `lm.ragged_decode_step` guards its logits — any
  active row whose logits are non-finite emits the negative
  ``FAILED_TOKEN`` sentinel instead of a sampled id (real ids are >= 0;
  `Request` rejects negative prompt ids). The host spots the sentinel in
  the step's *existing* single device→host transfer and retires only that
  row as ``FAILED`` (offending step in ``RequestState.error``); every
  other row's stream is bitwise unchanged (the guard's ``where`` is an
  identity on finite logits). A wall-clock **watchdog** (``watchdog_s`` /
  ``REPRO_WATCHDOG_S``) counts steps slower than its threshold into
  metrics (``watchdog_slow_steps``) so operators see degradation without
  the engine ever blocking on its own diagnosis, and ``run(timeout_s=)``
  bounds a drain in wall time.
* **Fault injection**: ``fault_hook`` (or ``REPRO_FAULTS``, parsed by
  `faults.FaultSchedule.from_spec`) is called once per step between
  bookkeeping and admission; `repro.serving.faults` drives deterministic
  chaos schedules through it (injected `PoolExhausted`, NaN logits via
  `Engine.inject_nan`, clock jumps, submit storms) and the chaos property
  test holds the engine to pool conservation + all-terminal outcomes
  under any schedule.

Observability
-------------

Every engine carries an `EngineMetrics` facade (``Engine.metrics``,
`repro.serving.metrics`): request-lifecycle events (submit → admit →
prefill-chunk → first-token → retire with reason), TTFT/TPOT/e2e/
queue-wait log-bucket histograms, per-step queue-depth / slot-occupancy /
free-block gauges, admission-backpressure counters (blocked on slots vs
blocks vs prefill budget), the horizon-waste account (slot-steps stranded
by mid-block retirement), and host/prefill/device phase timing around the
single compiled call. ``Engine.metrics.snapshot()`` exports the stable
operator schema; ``REPRO_METRICS_LOG`` appends lifecycle events as JSONL;
``REPRO_TRACE_DIR`` wraps `Engine.run` in a `jax.profiler` trace with
`named_scope` phase annotations. All of it is host-side observation —
metrics on vs off is bitwise invisible in the token streams (pinned by
`tests/test_metrics.py` and the `serving_metrics_overhead` gate).
"""

from __future__ import annotations

import os
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.serving.metrics import EngineMetrics
from repro.serving.paged import BlockPool, PoolExhausted, init_paged_cache
from repro.serving.request import (
    CANCELLED,
    FAILED,
    FINISHED,
    PREEMPTED,
    PREFILLING,
    QUEUED,
    RUNNING,
    TIMED_OUT,
    Request,
    RequestState,
    SamplingParams,
)
from repro.serving.scheduler import Scheduler

_ENGINE_FAMILIES = ("dense", "moe", "ssm", "hybrid")
# families whose prefill is order-sensitive end to end (recurrent state):
# bucket padding would corrupt the final state, so prompts prefill unpadded
_EXACT_LEN_FAMILIES = ("ssm", "hybrid")

# every terminal path funnels through _finish_state with one of these
# reasons; the two maps keep status / stats / metrics naming consistent
_STATUS_FOR_REASON = {"eos": FINISHED, "length": FINISHED,
                      "timeout": TIMED_OUT, "cancelled": CANCELLED,
                      "failed": FAILED}
_STAT_FOR_REASON = {"eos": "finished", "length": "finished",
                    "timeout": "timed_out", "cancelled": "cancelled",
                    "failed": "failed"}


class EngineStuck(RuntimeError):
    """`Engine.run` could not drain (step budget or wall-clock timeout
    exhausted with work still live). The message is a diagnostic dump —
    queue depth and last refusal, per-slot request status, pool and
    terminal-counter state — so a stuck-engine report is actionable
    without a debugger attached."""


class Engine:
    def __init__(self, params, cfg: ArchConfig, ctx: ModelContext, *,
                 n_slots: int = 4, max_len: int = 256,
                 scheduler: Optional[Scheduler] = None,
                 prefill_bucket: int = 16,
                 prefill_chunk: Optional[int] = None,
                 step_horizon: int = 1,
                 kv_block_size: Optional[int] = None,
                 kv_pool_tokens: Optional[int] = None,
                 overcommit: bool = False,
                 preempt_limit: int = 8,
                 base_seed: int = 0,
                 clock: Optional[callable] = None,
                 metrics: Union[bool, EngineMetrics, None] = None,
                 watchdog_s: Optional[float] = None,
                 fault_hook: Optional[callable] = None):
        if cfg.family not in _ENGINE_FAMILIES:
            raise NotImplementedError(
                f"continuous batching supports {_ENGINE_FAMILIES}, "
                f"got {cfg.family!r}")
        if prefill_chunk is not None and cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                "chunked prefill needs a pos-indexed KV cache "
                f"(dense/moe), got {cfg.family!r}")
        if step_horizon < 1:
            raise ValueError(f"step_horizon must be >= 1, got {step_horizon}")
        if overcommit and kv_block_size is None:
            raise ValueError(
                "overcommit=True needs a paged pool (pass kv_block_size): "
                "slot rows have nothing to overcommit")
        if preempt_limit < 1:
            raise ValueError(
                f"preempt_limit must be >= 1, got {preempt_limit}")
        self.overcommit = bool(overcommit)
        self.preempt_limit = preempt_limit
        self.params, self.cfg, self.ctx = params, cfg, ctx
        self.n_slots, self.max_len = n_slots, max_len
        # not `scheduler or ...`: an empty Scheduler is len()==0-falsy
        self.scheduler = scheduler if scheduler is not None else Scheduler()
        self.prefill_bucket = max(1, prefill_bucket)
        self.prefill_chunk = prefill_chunk
        self.step_horizon = step_horizon
        self._base_key = jax.random.PRNGKey(base_seed)
        # every latency stamp goes through this monotonic clock (wall
        # clock steps — NTP, suspend — must never reach TTFT/TPOT math);
        # tests inject metrics.FakeClock for deterministic latencies
        self.clock = clock if clock is not None else time.perf_counter
        if isinstance(metrics, EngineMetrics):
            self.metrics = metrics
        else:
            # metrics are host-side observers only: enabled or not, the
            # engine's device calls and token streams are bitwise
            # identical (pinned by tests + the run.py overhead gate)
            self.metrics = EngineMetrics(
                enabled=True if metrics is None else bool(metrics),
                clock=self.clock)
        # wall-clock watchdog: steps slower than this are counted (never
        # interrupted) — surfacing degradation is observability's job,
        # blocking the loop to report slowness would be self-inflicted
        if watchdog_s is None:
            env = os.environ.get("REPRO_WATCHDOG_S")
            watchdog_s = float(env) if env else None
        if watchdog_s is not None and watchdog_s <= 0:
            raise ValueError(f"watchdog_s must be > 0, got {watchdog_s}")
        self.watchdog_s = watchdog_s
        # fault injection point (tests / chaos harness): called once per
        # step between bookkeeping and admission. REPRO_FAULTS installs a
        # FaultSchedule from its spec when no explicit hook is passed.
        if fault_hook is None:
            spec = os.environ.get("REPRO_FAULTS")
            if spec:
                from repro.serving.faults import FaultSchedule
                fault_hook = FaultSchedule.from_spec(spec)
        self.fault_hook = fault_hook

        self.pool: Optional[BlockPool] = None
        if kv_block_size is not None:
            if cfg.family not in ("dense", "moe"):
                raise NotImplementedError(
                    "paged KV needs a pos-indexed pure-attention cache "
                    f"(dense/moe), got {cfg.family!r}")
            if max_len % kv_block_size:
                raise ValueError(
                    f"max_len ({max_len}) must be a multiple of "
                    f"kv_block_size ({kv_block_size})")
            pool_tokens = n_slots * max_len if kv_pool_tokens is None \
                else kv_pool_tokens
            if pool_tokens % kv_block_size:
                raise ValueError(
                    f"kv_pool_tokens ({pool_tokens}) must be a multiple of "
                    f"kv_block_size ({kv_block_size})")
            self.pool = BlockPool(pool_tokens // kv_block_size,
                                  kv_block_size, n_slots=n_slots,
                                  max_blocks=max_len // kv_block_size,
                                  optimistic=self.overcommit)
            self.cache = init_paged_cache(cfg, self.pool)
        else:
            if kv_pool_tokens is not None:
                raise ValueError(
                    "kv_pool_tokens only applies to paged mode — pass "
                    "kv_block_size as well (silently building slot rows "
                    "would ignore the requested budget)")
            cache = lm.init_cache(cfg, n_slots, max_len)
            cache.pop("pos")  # positions are per-row, threaded per step
            self.cache = cache
        self._tok = jnp.zeros((n_slots, 1), jnp.int32)
        # host mirrors of the per-row state (python bookkeeping reads
        # these); the device copies in self._dev are the step inputs
        self._pos = np.zeros(n_slots, np.int32)
        self._active = np.zeros(n_slots, bool)
        self._greedy = np.ones(n_slots, bool)
        self._temp = np.ones(n_slots, np.float32)
        self._top_k = np.zeros(n_slots, np.int32)
        self._top_p = np.zeros(n_slots, np.float32)
        self._seed = np.zeros(n_slots, np.int32)
        self._n_sampled = np.zeros(n_slots, np.int32)
        # fault-injection state: _poison mirrors the device NaN-injection
        # mask (lazily created on first inject_nan; None compiles the
        # injection out of the step entirely), _fault_exhaust_once arms
        # one synthetic PoolExhausted at the next ensure.
        self._poison: Optional[np.ndarray] = None
        self._fault_exhaust_once = False
        self._dev: dict[str, jax.Array] = {}
        self._push_rows()
        self._dirty = False
        self._slots: list[Optional[RequestState]] = [None] * n_slots

        self._pending: Optional[np.ndarray] = None
        self._pending_slots: list[tuple[int, RequestState]] = []
        self._pending_step = 0  # stats["steps"] of the step that decoded it
        self._next_id = 0
        self._auto_seed = 0
        # request_id -> live (non-terminal) state, for cancel(); count of
        # live deadline-carrying requests so the per-step sweep is free
        # for deadline-less traffic
        self._live: dict[int, RequestState] = {}
        self._deadlined = 0
        # EWMA of step wall time, feeding the TTFT-hopeless estimate.
        # Starts at 0.0: until real steps have run the engine never
        # second-guesses admission (and FakeClock tests stay exact —
        # only hard-expired deadlines fire).
        self._step_ewma = 0.0
        self.stats = {"steps": 0, "device_steps": 0, "transfers": 0,
                      "occupancy_sum": 0.0, "tokens_out": 0,
                      "admitted": 0, "finished": 0, "prefill_chunks": 0,
                      "peak_running": 0, "horizon": step_horizon,
                      "preemptions": 0, "replayed_tokens": 0,
                      "timed_out": 0, "cancelled": 0, "failed": 0,
                      "slow_steps": 0}

        # params are engine-constant: captured in the jit closures so the
        # (large) param tree is never flattened/hashed per call; `sample`
        # is a static flag — the all-greedy specialization compiles the
        # sampler out of the hot loop (greedy tokens are flag-invariant)
        self._step_fn = jax.jit(self._raw_step, static_argnums=(12,))
        self._admit_fns: dict[tuple[int, int, bool], callable] = {}
        # chunk processors, compiled once per (REPRO_CHUNK_ATTN mode,
        # prefix bucket) — the mode is read at trace time inside the
        # jitted fns, so an A/B flip on a live engine must not reuse a
        # function traced under the previous mode; the bucket is the
        # static power-of-two bound the XLA fallback slices the cache to
        # (at most log2(max_len) specializations per mode)
        self._chunk_fn_cache: dict[tuple[str, int], tuple] = {}

    def _push_rows(self) -> None:
        """Refresh the device copies of the per-row vectors from the host
        mirrors — called only when a slot event (admit/retire) changed
        them; between events, pos/step advance on device inside the step
        and the mirrors replay the same update host-side."""
        self._dev = {
            "pos": jnp.asarray(self._pos),
            "step": jnp.asarray(self._n_sampled),
            "active": jnp.asarray(self._active),
            "greedy": jnp.asarray(self._greedy),
            "temp": jnp.asarray(self._temp),
            "top_k": jnp.asarray(self._top_k),
            "top_p": jnp.asarray(self._top_p),
            "seed": jnp.asarray(self._seed),
            # paged mode: the block tables ride along with the row state
            # (tiny int32 host→device upload, only on slot/block events)
            "bt": None if self.pool is None else jnp.asarray(self.pool.table),
            # NaN-injection mask (fault harness only): None until the
            # first inject_nan, which keeps it out of the compiled step
            "poison": None if self._poison is None
            else jnp.asarray(self._poison),
        }

    # ------------------------------------------------------------------
    # jitted device functions
    # ------------------------------------------------------------------

    def _raw_step(self, cache, tok, pos, step, active, greedy, temp,
                  top_k, top_p, seed, bt, poison, sample):
        """H = step_horizon ragged decode steps as one lax.scan; emits the
        H consumed tokens (the stream the host appends) and the advanced
        carry. Inactive rows freeze inside ragged_decode_step. ``bt`` is
        the (B, max_blocks) block-table array in paged mode, else None;
        the host pre-maps every block the horizon can touch, so the tables
        are loop-invariant across the scan. ``poison`` is the (B,) NaN
        fault-injection mask (None outside the fault harness)."""
        base = {"greedy": greedy, "temperature": temp, "top_k": top_k,
                "top_p": top_p, "seed": seed}

        def body(carry, _):
            tok, pos, step, cache = carry
            nxt, nc = lm.ragged_decode_step(
                self.params, cache, tok, pos, active,
                dict(base, step=step), self._base_key, self.cfg, self.ctx,
                sample=sample, block_tables=bt, poison=poison)
            new_pos = nc.pop("pos")
            new_step = step + active.astype(jnp.int32)
            return (nxt, new_pos, new_step, nc), tok

        # named for REPRO_TRACE_DIR profiles: the horizon decode block
        with jax.named_scope("repro.engine.decode_horizon"):
            (tok, pos, step, cache), emitted = jax.lax.scan(
                body, (tok, pos, step, cache), None, length=self.step_horizon)
        return emitted, tok, pos, step, cache

    def _insert_rows(self, pool: dict, rows: dict, slots) -> dict:
        """Scatter a batch-k prefill cache into pool rows ``slots``
        (axis 1). ``rows`` leaves are (L, k, ...); slots is (k,) int32."""
        def one(p, r):
            return p.at[:, slots].set(r.astype(p.dtype))

        return {k: jax.tree.map(one, pool[k], rows[k]) for k in pool}

    def _first_tokens(self, logits, seed, temp, top_k, top_p, greedy,
                      sample: bool):
        """Sample the k admitted requests' first tokens (sample index 0)."""
        arg = jnp.argmax(logits, -1).astype(jnp.int32)
        if not sample:
            return arg
        fold = lambda s: jax.random.fold_in(
            jax.random.fold_in(self._base_key, s), jnp.int32(0))
        keys = jax.vmap(fold)(seed)
        sampled = lm.sample_logits_ragged(
            logits, keys, temperature=temp, top_k=top_k, top_p=top_p,
            vocab_size=self.cfg.vocab_size)
        return jnp.where(greedy[:, None], arg, sampled)

    def _insert_blocks(self, pool_cache: dict, rows: dict, phys) -> dict:
        """Scatter a batch-k prefill cache into the paged pool. ``rows``
        leaves are (L, k, KVH, P, ...) with P a whole number of blocks;
        ``phys`` is (k, P // block_size) int32 physical block ids — the
        blocks the pool mapped for these slots at admission."""
        bs = self.pool.block_size
        flat = phys.reshape(-1)

        def one(p, r):
            ell, k, kvh = r.shape[0], r.shape[1], r.shape[2]
            nb = r.shape[3] // bs
            if r.ndim == 5:
                rb = r.reshape(ell, k, kvh, nb, bs, r.shape[4]) \
                     .transpose(0, 1, 3, 2, 4, 5) \
                     .reshape(ell, k * nb, kvh, bs, r.shape[4])
            else:
                rb = r.reshape(ell, k, kvh, nb, bs) \
                     .transpose(0, 1, 3, 2, 4) \
                     .reshape(ell, k * nb, kvh, bs)
            return p.at[:, flat].set(rb.astype(p.dtype))

        return {"attn": jax.tree.map(one, pool_cache["attn"], rows["attn"])}

    def _admit_fn(self, padded_len: int, k: int, sample: bool):
        """Batched prefill-and-install for k same-bucket admissions,
        compiled once per (bucket length, k, sampling?)."""
        if (padded_len, k, sample) not in self._admit_fns:
            if self.pool is None:
                def f(cache, tok, toks, last_pos, slots, seed, temp, top_k,
                      top_p, greedy):
                    with jax.named_scope("repro.engine.admit"):
                        logits, rows = lm.prefill(
                            self.params, toks, self.cfg, self.ctx,
                            max_len=self.max_len, last_pos=last_pos)
                        new_cache = self._insert_rows(cache, rows, slots)
                        first = self._first_tokens(logits, seed, temp, top_k,
                                                   top_p, greedy, sample)
                        tok = tok.at[slots].set(first)
                    return tok, new_cache
            else:
                # paged: the prefill KV is padded only to whole blocks
                # (not max_len) and scattered straight into the pool
                bs = self.pool.block_size
                p_len = -(-padded_len // bs) * bs

                def f(cache, tok, toks, last_pos, slots, phys, seed, temp,
                      top_k, top_p, greedy):
                    with jax.named_scope("repro.engine.admit"):
                        logits, rows = lm.prefill(
                            self.params, toks, self.cfg, self.ctx,
                            max_len=p_len, last_pos=last_pos)
                        new_cache = self._insert_blocks(cache, rows, phys)
                        first = self._first_tokens(logits, seed, temp, top_k,
                                                   top_p, greedy, sample)
                        tok = tok.at[slots].set(first)
                    return tok, new_cache

            self._admit_fns[(padded_len, k, sample)] = jax.jit(f)
        return self._admit_fns[(padded_len, k, sample)]

    def _prefix_bucket(self, end: int) -> int:
        """Static prefix bound for one chunk call: ``end = start + C``
        rounded up to a power of two (at most log2(max_len) jit
        specializations per chunk shape), then to whole KV blocks in
        paged mode (the gather fallback trims to whole pages), capped at
        max_len. The XLA chunk-attention fallback slices the cache to
        this, so the off-TPU per-chunk cost is O(bucket), not O(max_len).
        The Pallas kernel ignores it (its clamp is the scalar-prefetched
        ``start`` itself) — so when the chunk attention will lower to the
        kernel, everything collapses to ONE bucket (max_len): bucketed
        specializations would only buy redundant whole-model recompiles
        there. The kernel-vs-fallback call mirrors `ops.chunk_attention`'s
        own dispatch — the ctx's explicit backend/interpret win over the
        env default, exactly as they do at the call site."""
        mode = os.environ.get("REPRO_CHUNK_ATTN", "pallas")
        backend = self.ctx.backend
        resolved = kops.default_backend() if backend == "auto" else backend
        if mode == "pallas" and (resolved == "pallas" or self.ctx.interpret):
            return self.max_len
        b = 1
        while b < end:
            b <<= 1
        if self.pool is not None:
            bs = self.pool.block_size
            b = -(-b // bs) * bs
        return min(b, self.max_len)

    def _chunk_fns(self, bucket: int):
        """(mid, last) chunk processors, compiled once per (engine,
        REPRO_CHUNK_ATTN mode, prefix bucket). Slot-row mode slices the
        slot's cache row in/out; paged mode passes the pool leaves whole
        plus the slot's block-table row (the chunk's writes and reads
        resolve through it)."""
        key = (os.environ.get("REPRO_CHUNK_ATTN", "pallas"), bucket)
        if key not in self._chunk_fn_cache:
            if self.pool is None:
                def row_of(cache, slot):
                    return jax.tree.map(
                        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1,
                                                               axis=1),
                        cache["attn"])

                def insert(cache, row, slot):
                    def one(p, r):
                        start = (0, slot) + (0,) * (p.ndim - 2)
                        return jax.lax.dynamic_update_slice(
                            p, r.astype(p.dtype), start)

                    return {"attn": jax.tree.map(one, cache["attn"], row)}

                def mid(cache, toks, start, slot):
                    row = row_of(cache, slot)
                    _, row = lm.prefill_chunk(self.params, row, toks, start,
                                              self.cfg, self.ctx,
                                              prefix_bucket=bucket)
                    return insert(cache, row, slot)

                def last(cache, tok, toks, start, slot, last_pos, seed, temp,
                         top_k, top_p, greedy):
                    row = row_of(cache, slot)
                    logits, row = lm.prefill_chunk(self.params, row, toks,
                                                   start, self.cfg, self.ctx,
                                                   last_pos=last_pos,
                                                   prefix_bucket=bucket)
                    new_cache = insert(cache, row, slot)
                    first = self._first_tokens(
                        logits, seed[None], temp[None], top_k[None],
                        top_p[None], greedy[None], True)
                    tok = jax.lax.dynamic_update_slice(tok, first, (slot, 0))
                    return tok, new_cache
            else:
                def mid(cache, toks, start, bt):
                    _, attn = lm.prefill_chunk(self.params, cache["attn"],
                                               toks, start, self.cfg,
                                               self.ctx, block_tables=bt,
                                               prefix_bucket=bucket)
                    return {"attn": attn}

                def last(cache, tok, toks, start, slot, bt, last_pos, seed,
                         temp, top_k, top_p, greedy):
                    logits, attn = lm.prefill_chunk(self.params,
                                                    cache["attn"], toks,
                                                    start, self.cfg, self.ctx,
                                                    last_pos=last_pos,
                                                    block_tables=bt,
                                                    prefix_bucket=bucket)
                    new_cache = {"attn": attn}
                    first = self._first_tokens(
                        logits, seed[None], temp[None], top_k[None],
                        top_p[None], greedy[None], True)
                    tok = jax.lax.dynamic_update_slice(tok, first, (slot, 0))
                    return tok, new_cache

            self._chunk_fn_cache[key] = (jax.jit(mid), jax.jit(last))
        return self._chunk_fn_cache[key]

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, request: Union[Request, Sequence[int]], **kw
               ) -> RequestState:
        """Queue a request. Accepts a `Request` or a raw prompt (token ids)
        plus Request kwargs. Returns the live `RequestState` (its
        ``tokens`` list streams while the engine runs)."""
        if not isinstance(request, Request):
            request = Request(prompt=tuple(request), **kw)
        L = len(request.prompt)
        extent = self._prefill_extent(L)
        need = self._need_tokens(request)
        if need > self.max_len:
            raise ValueError(
                f"prompt ({L}, padded prefill extent {extent}) + "
                f"max_new_tokens ({request.max_new_tokens}) + horizon "
                f"headroom ({self.step_horizon - 1}) exceeds cache max_len "
                f"({self.max_len})")
        if self.pool is not None \
                and self.pool.blocks_for(need) > self.pool.n_blocks:
            raise ValueError(
                f"request needs {self.pool.blocks_for(need)} KV blocks but "
                f"the pool only has {self.pool.n_blocks} — it could never "
                "be admitted")
        state = RequestState(request=request, request_id=self._next_id,
                             arrival_t=time.time(), submit_t=self.clock())
        self._next_id += 1
        self._live[state.request_id] = state
        if request.deadline_s is not None \
                or request.ttft_deadline_s is not None:
            self._deadlined += 1
        self.scheduler.submit(state)
        self.metrics.on_submit(state)
        return state

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(len(self.scheduler)) or any(
            s is not None for s in self._slots)

    def step(self) -> None:
        """One engine step: emit+retire, admit, advance prefills, decode a
        horizon block. Exactly one device→host transfer (the stacked-token
        block) happens per step with any running row.

        Telemetry rides the loop without touching it: lifecycle hooks
        (first token, retire+reason, admit) fire as the host observes the
        events, per-step gauges (queue depth / occupancy / free blocks)
        are sampled once before the device call, and the step's wall time
        is split into host / admission-prefill / device phases — the
        device phase brackets the single compiled call plus its transfer,
        which is where the step blocks. All of it is host-side python;
        metrics on vs off cannot change a token."""
        mx = self.metrics
        rec = mx.enabled
        # watchdog base: read before the fault hook so injected clock
        # jumps register as slow steps (what a real stall looks like)
        t_step0 = self.clock()
        t0 = t_step0 if rec else 0.0
        t_prefill = 0.0
        self.stats["steps"] += 1
        mx.count("steps")

        # 1) bookkeeping for the token block produced last step
        if self._pending is not None:
            now = self.clock()
            H = self._pending.shape[0]
            for slot, st in self._pending_slots:
                if st.done:
                    # retired between steps (cancel / deadline sweep):
                    # its pending tokens are dropped, never applied
                    continue
                for h in range(H):
                    t = int(self._pending[h, slot, 0])
                    if t < 0:
                        # the device guard's FAILED sentinel: this row's
                        # logits went non-finite. Retire only this row;
                        # every other row's stream is untouched (the
                        # guard is an identity on finite logits).
                        self._retire(
                            slot, st, "failed", horizon_waste=H - 1 - h,
                            error={"kind": "non_finite_logits",
                                   "step": self._pending_step,
                                   "horizon_index": h,
                                   "tokens_streamed": len(st.tokens)})
                        break
                    if st.replay_left > 0:
                        # deterministic replay of a resumed request: the
                        # decode path just re-derived a token the client
                        # already has — verify and drop the duplicate
                        expect = st.tokens[len(st.tokens) - st.replay_left]
                        if t != expect:
                            raise RuntimeError(
                                f"resume replay diverged for request "
                                f"{st.request_id}: re-derived {t}, snapshot "
                                f"has {expect} — decode replay must be "
                                "bitwise deterministic for overcommit")
                        st.replay_left -= 1
                        self.stats["replayed_tokens"] += 1
                        continue
                    st.tokens.append(t)
                    st.token_times.append(now)
                    self.stats["tokens_out"] += 1
                    mx.count("tokens_out")
                    if len(st.tokens) == 1:
                        st.first_token_t = now
                        mx.on_first_token(st)
                    reason = self.scheduler.finish_reason(st)
                    if reason is not None:
                        # a mid-block finish strands the rest of the
                        # horizon: H-1-h slot-steps of device work whose
                        # tokens are discarded (the horizon-waste account)
                        self._retire(slot, st, reason,
                                     horizon_waste=H - 1 - h)
                        break
            self._pending = None
            self._pending_slots = []

        # 1b) fault injection (chaos harness / tests): after bookkeeping —
        # the last block's tokens are accounted before any injected
        # cancel/poison — and before admission, so injected submits and
        # deadline expiries see this step's scheduling.
        if self.fault_hook is not None:
            self.fault_hook(self)

        # 1c) deadline sweep: only while any live request carries one.
        # Queued/preempted expiries leave the heap; slotted expiries free
        # their slot (and blocks) like any retirement. TTFT-hopeless
        # queued work — admission + prefill cannot beat its remaining
        # budget at the recent step pace — is expired here too, instead
        # of wasting prefill on a request whose client already gave up.
        if self._deadlined:
            now = self.clock()
            for st in self.scheduler.states():
                if self._expired(st, now) or self._ttft_hopeless(st, now):
                    self.scheduler.remove(st)
                    self._finish_queued(st, "timeout")
            for slot, st in enumerate(self._slots):
                if st is not None and self._expired(st, now):
                    self._retire(slot, st, "timeout")

        # 2) admission into free slots (freed this step included);
        # same-bucket admissions batch into one compiled call. In paged
        # mode admission additionally gates on free-block count: a request
        # only reserves its own worst-case blocks (not a max_len row), so
        # short requests pack — but when the pool runs dry the head of the
        # queue waits (clean backpressure, no reordering past it).
        free = [i for i, s in enumerate(self._slots) if s is None]
        blocked = None  # this step's backpressure attribution (one count)
        if free:
            can_admit = None
            if self.pool is not None:
                tentative = {"blocks": 0}
                if self.overcommit:
                    # optimistic: price a request at the blocks its
                    # prefill extent touches *now*, not its worst case —
                    # the decode frontier preempts if the bet goes bad
                    def can_admit(st, _t=tentative):
                        nb = self.pool.blocks_for(
                            self._prefill_extent(st.prompt_len))
                        if self.pool.can_alloc(_t["blocks"] + nb):
                            _t["blocks"] += nb
                            return True
                        return False
                else:
                    def can_admit(st, _t=tentative):
                        nb = self.pool.blocks_for(
                            self._need_tokens(st.request))
                        if self.pool.can_reserve(_t["blocks"] + nb):
                            _t["blocks"] += nb
                            return True
                        return False

            admits = self.scheduler.pop_admissions(len(free),
                                                   self.prefill_chunk,
                                                   can_admit=can_admit)
            batch: dict[int, list[tuple[RequestState, int]]] = {}
            for st in admits:
                slot = free.pop(0)
                st.slot = slot
                st.admit_t = self.clock()
                self._slots[slot] = st
                self._set_row_params(slot, st)
                if self.pool is not None and not self.overcommit:
                    self.pool.reserve(
                        slot,
                        self.pool.blocks_for(self._need_tokens(st.request)))
                self.stats["admitted"] += 1
                mx.on_admit(st)
                if st.status == PREEMPTED:
                    # resume = replay: re-prefill the original prompt and
                    # re-decode the snapshot before emitting anything new
                    st.replay_left = len(st.tokens)
                    mx.on_resume(st, st.prompt_len + len(st.tokens))
                st.status = QUEUED  # normalized below to PREFILLING/RUNNING
                if self.prefill_chunk is not None \
                        and st.prompt_len > self.prefill_chunk:
                    st.status = PREFILLING
                    st.prefill_pos = 0
                else:
                    batch.setdefault(self._padded_len(st.prompt_len),
                                     []).append((st, slot))
            if len(self.scheduler) and free:
                # slots left over but the queue head refused: the pool
                # (can_admit → "resource") or the prefill budget
                blocked = {"resource": "blocks", "budget": "budget"}.get(
                    self.scheduler.last_refusal)
            for padded, group in batch.items():
                tp = self.clock() if rec else 0.0
                self._admit_group(
                    padded, group,
                    any(not st.request.sampling.greedy for st, _ in group))
                if rec:
                    t_prefill += self.clock() - tp
        elif len(self.scheduler):
            blocked = "slots"  # queued work, zero free slots
        if blocked is not None:
            mx.on_blocked(blocked)

        # 3) chunked-prefill rows advance one chunk
        for slot, st in enumerate(self._slots):
            if st is not None and st.status == PREFILLING:
                tp = self.clock() if rec else 0.0
                self._advance_prefill(slot, st)
                if rec:
                    t_prefill += self.clock() - tp

        # 4) device step (one jitted call decoding `step_horizon` tokens),
        # then the block's ONE device→host transfer
        running = [(i, s) for i, s in enumerate(self._slots)
                   if s is not None and s.status == RUNNING]
        if running and self.pool is not None:
            # alloc-on-demand: map every block the horizon's writes can
            # touch (positions pos .. pos+H-1) before the compiled step
            # runs. Conservative mode: within-reservation, can never
            # fail. Overcommit: an exhausted free list preempts a victim
            # (possibly this very row) and retries.
            bs = self.pool.block_size
            for slot, st in running:
                if self._slots[slot] is not st:
                    continue  # already evicted as a victim this step
                n = -(-(int(self._pos[slot]) + self.step_horizon) // bs)
                if self.overcommit:
                    self._ensure_evicting(slot, n)
                elif self.pool.ensure(slot, n):
                    self._dirty = True
            running = [(i, s) for i, s in enumerate(self._slots)
                       if s is not None and s.status == RUNNING]
        mx.sample_step(
            queue_depth=len(self.scheduler), running=len(running),
            n_slots=self.n_slots,
            free_blocks=None if self.pool is None else self.pool.free_blocks)
        if running:
            if self._dirty:
                self._push_rows()
                self._dirty = False
            self.stats["occupancy_sum"] += len(running) / self.n_slots
            self.stats["peak_running"] = max(self.stats["peak_running"],
                                             len(running))
            self.stats["transfers"] += 1
            self.stats["device_steps"] += 1
            mx.count("device_steps")
            d = self._dev
            sample = any(not s.request.sampling.greedy for _, s in running)
            td0 = self.clock() if rec else 0.0
            emitted, self._tok, d["pos"], d["step"], self.cache = \
                self._step_fn(self.cache, self._tok, d["pos"], d["step"],
                              d["active"], d["greedy"], d["temp"],
                              d["top_k"], d["top_p"], d["seed"], d["bt"],
                              d["poison"], sample)
            self._pending = np.asarray(emitted)  # one device→host transfer
            self._pending_slots = running
            self._pending_step = self.stats["steps"]
            if self._poison is not None and self._poison.any():
                # one-shot: the injected NaN fired this step; disarm so
                # the next step's logits are clean again
                self._poison[:] = False
                self._dirty = True
            # replay the device update on the host mirrors (no transfer)
            h = self.step_horizon
            self._pos = np.where(self._active, self._pos + h, self._pos)
            self._n_sampled = self._n_sampled + h * self._active
            if rec:
                # the np.asarray above blocked on the device result, so
                # td1-td0 brackets the compiled horizon call + transfer
                td1 = self.clock()
                mx.observe_step(
                    host_s=(self.clock() - t0) - (td1 - td0) - t_prefill,
                    prefill_s=t_prefill, device_s=td1 - td0)
        elif rec:
            mx.observe_step(host_s=(self.clock() - t0) - t_prefill,
                            prefill_s=t_prefill)

        # 5) watchdog + step-pace EWMA: count (never interrupt) steps
        # slower than the threshold; the EWMA feeds the TTFT-hopeless
        # admission estimate.
        dt = self.clock() - t_step0
        self._step_ewma = 0.2 * dt + 0.8 * self._step_ewma
        if self.watchdog_s is not None and dt > self.watchdog_s:
            self.stats["slow_steps"] += 1
            mx.count("watchdog_slow_steps")
            mx.event("watchdog_slow_step", step=self.stats["steps"],
                     duration_s=dt)

    def run(self, max_steps: int = 1_000_000,
            timeout_s: Optional[float] = None) -> None:
        """Drain: step until queue and slots are empty. ``timeout_s``
        bounds the drain in wall time (the monotonic clock) — on either
        budget running out, `EngineStuck` carries a full diagnostic dump
        instead of hanging the caller or raising a bare error. With
        ``REPRO_TRACE_DIR`` set, the drain runs under a `jax.profiler`
        trace written to that directory — the compiled admit/chunk/decode
        calls carry `jax.named_scope` annotations (``repro.engine.*``,
        ``repro.prefill`` / ``repro.decode_step`` in `models/lm.py`), so
        the trace attributes device time to serving phases."""
        trace_dir = os.environ.get("REPRO_TRACE_DIR")
        if trace_dir:
            with jax.profiler.trace(trace_dir):
                return self._drain(max_steps, timeout_s)
        return self._drain(max_steps, timeout_s)

    def _drain(self, max_steps: int,
               timeout_s: Optional[float] = None) -> None:
        t0 = self.clock()
        for _ in range(max_steps):
            if not self.has_work():
                return
            if timeout_s is not None and self.clock() - t0 > timeout_s:
                raise EngineStuck(self._diagnose(
                    f"engine did not drain within timeout_s={timeout_s}"))
            self.step()
        raise EngineStuck(self._diagnose(
            f"engine did not drain in {max_steps} steps"))

    def _diagnose(self, reason: str) -> str:
        """Multi-line stuck-engine dump: everything a report needs to be
        actionable — where the work is (queue vs slots), why admission
        last refused, how the pool stands, and the terminal counters."""
        lines = [reason,
                 f"  queue: depth={len(self.scheduler)} "
                 f"last_refusal={self.scheduler.last_refusal!r}"]
        for i, st in enumerate(self._slots):
            if st is None:
                lines.append(f"  slot {i}: free")
            else:
                lines.append(
                    f"  slot {i}: request {st.request_id} {st.status} "
                    f"pos={int(self._pos[i])} "
                    f"tokens={len(st.tokens)}/{st.request.max_new_tokens} "
                    f"preempts={st.preempt_count}")
        if self.pool is not None:
            p = self.pool.stats()
            lines.append(
                f"  pool: free={p['free_blocks']}/{p['n_blocks']} blocks, "
                f"reserved={p['reserved_blocks']} "
                f"alloc_failures={p['alloc_failures']} "
                f"optimistic={p['optimistic']}")
        s = self.stats
        lines.append(
            f"  stats: steps={s['steps']} finished={s['finished']} "
            f"timed_out={s['timed_out']} cancelled={s['cancelled']} "
            f"failed={s['failed']} preemptions={s['preemptions']} "
            f"slow_steps={s['slow_steps']}")
        return "\n".join(lines)

    # ------------------------------------------------------------------
    # admission / retirement internals
    # ------------------------------------------------------------------

    def _padded_len(self, L: int) -> int:
        if self.cfg.family in _EXACT_LEN_FAMILIES:
            return L  # recurrent prefill state is order-sensitive: no pad
        b = self.prefill_bucket
        return -(-L // b) * b

    def _prefill_extent(self, L: int) -> int:
        """Cache positions the admission prefill writes (incl. padding)."""
        if self.prefill_chunk is not None and L > self.prefill_chunk:
            # chunked prefill pads the final chunk to a full chunk width
            extent = -(-L // self.prefill_chunk) * self.prefill_chunk
        else:
            extent = self._padded_len(L)  # bucket-padded one-shot prefill
        if self.pool is not None:
            # the paged prefill scatters whole blocks into the pool
            bs = self.pool.block_size
            extent = -(-extent // bs) * bs
        return extent

    def _need_tokens(self, request: Request) -> int:
        """Worst-case cache positions the request can touch — what the
        slot-row path sizes against max_len and the paged path reserves
        blocks for (the horizon tail: a row finishing mid-block still
        writes through the end of its block)."""
        L = len(request.prompt)
        return max(self._prefill_extent(L),
                   L + request.max_new_tokens + self.step_horizon - 1)

    def _set_row_params(self, slot: int, st: RequestState) -> None:
        sp = st.request.sampling
        self._greedy[slot] = sp.greedy
        self._temp[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._seed[slot] = sp.seed

    def _admit_group(self, padded: int, group, sample: bool) -> None:
        """One compiled prefill+install call for k same-bucket requests."""
        k = len(group)
        toks = np.zeros((k, padded), np.int32)
        slots = np.zeros(k, np.int32)
        last = np.zeros(k, np.int32)
        for j, (st, slot) in enumerate(group):
            toks[j, : st.prompt_len] = st.request.prompt
            slots[j] = slot
            last[j] = st.prompt_len - 1
        fn = self._admit_fn(padded, k, sample)
        if self.pool is not None:
            bs = self.pool.block_size
            nb = -(-padded // bs)
            for _, slot in group:
                self.pool.ensure(slot, nb)  # map the prefill extent
            phys = jnp.asarray(self.pool.table[slots, :nb])
            self._tok, self.cache = fn(
                self.cache, self._tok, jnp.asarray(toks), last, slots, phys,
                self._seed[slots], self._temp[slots], self._top_k[slots],
                self._top_p[slots], self._greedy[slots])
        else:
            self._tok, self.cache = fn(
                self.cache, self._tok, jnp.asarray(toks), last, slots,
                self._seed[slots], self._temp[slots], self._top_k[slots],
                self._top_p[slots], self._greedy[slots])
        for st, slot in group:
            self._start_running(slot, st, st.prompt_len)

    def _advance_prefill(self, slot: int, st: RequestState) -> None:
        chunk = self.prefill_chunk
        L = st.prompt_len
        start = st.prefill_pos
        end = min(start + chunk, L)
        bt = None
        if self.pool is not None:
            # pre-map every block the chunk's writes (and the kernel's
            # clamped reads) can touch before the compiled call. Within
            # the admission reservation this can never fail; in
            # overcommit mode an exhausted pool preempts a victim —
            # possibly this very row, which then skips its chunk.
            n = -(-(start + chunk) // self.pool.block_size)
            if self.overcommit:
                if not self._ensure_evicting(slot, n):
                    return  # evicted to cover the demand; re-queued
            elif self.pool.ensure(slot, n):
                self._dirty = True
            bt = jnp.asarray(self.pool.table[slot:slot + 1])
        self.metrics.on_prefill_chunk(st, start, end)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, : end - start] = st.request.prompt[start:end]
        # the chunk writes its full (padded) width: positions
        # start .. start+chunk-1 — the static prefix bucket bounds that
        bucket = self._prefix_bucket(start + chunk)
        mid, last = self._chunk_fns(bucket)
        self.stats["prefill_chunks"] += 1
        if end < L:
            if self.pool is None:
                self.cache = mid(self.cache, jnp.asarray(toks),
                                 np.int32(start), np.int32(slot))
            else:
                self.cache = mid(self.cache, jnp.asarray(toks),
                                 np.int32(start), bt)
            st.prefill_pos = end
            # track the prefill frontier: the row is frozen for decode, but
            # the compiled step still executes its KV write — at `pos`. By
            # keeping pos at the frontier, that garbage write lands in the
            # NEXT chunk's span and is overwritten before it can ever be
            # attended (a stale pos would let it land inside the prefix a
            # previous chunk already wrote; in paged mode an unmapped
            # frontier block sends it to TRASH, a mapped one is overwritten
            # by the next chunk the same way)
            self._pos[slot] = end
            self._dirty = True
        else:
            if self.pool is None:
                self._tok, self.cache = last(
                    self.cache, self._tok, jnp.asarray(toks), np.int32(start),
                    np.int32(slot), np.int32(L - 1 - start),
                    self._seed[slot], self._temp[slot], self._top_k[slot],
                    self._top_p[slot], self._greedy[slot])
            else:
                self._tok, self.cache = last(
                    self.cache, self._tok, jnp.asarray(toks), np.int32(start),
                    np.int32(slot), bt, np.int32(L - 1 - start),
                    self._seed[slot], self._temp[slot], self._top_k[slot],
                    self._top_p[slot], self._greedy[slot])
            st.prefill_pos = L
            self._start_running(slot, st, L)

    def _start_running(self, slot: int, st: RequestState, L: int) -> None:
        st.status = RUNNING
        self._pos[slot] = L
        self._active[slot] = True
        self._n_sampled[slot] = 1  # the first token was sampled at admit
        self._dirty = True

    def _pick_victim(self) -> Optional[tuple]:
        """Victim policy for an exhausted pool: the lowest-priority,
        youngest-arrival occupied slot. The highest-priority *oldest*
        occupied row is protected (never evicted), so at least one row
        always runs to completion — the liveness anchor. Rows at the
        ``preempt_limit`` fairness bound are passed over while any other
        candidate exists. Returns (slot, state) or None (nothing
        evictable: at most one occupied row)."""
        occ = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if len(occ) < 2:
            # a lone row's demand always fits: submit() rejected anything
            # whose worst case exceeds the whole pool
            return None
        order = lambda e: (e[1].request.priority, e[1].queue_seq)
        protected = min(occ, key=order)
        cand = [e for e in occ if e is not protected]
        fair = [e for e in cand
                if e[1].preempt_count < self.preempt_limit]
        if fair:
            return max(fair, key=order)
        # every candidate is over the fairness bound (pathological
        # pressure): spread the pain — evict the row with the fewest
        # evictions so no single request absorbs the churn
        return min(cand, key=lambda e: (e[1].preempt_count,
                                        -e[1].request.priority,
                                        -e[1].queue_seq))

    def _preempt(self, slot: int, st: RequestState) -> None:
        """Evict ``st`` from its slot: reclaim its pool blocks, snapshot
        its emitted tokens (they stay on the state — clients keep them),
        and re-queue it at its original (priority, arrival) position for
        a replay resume."""
        st.preempt_count += 1
        freed = 0 if self.pool is None else self.pool.release(slot)
        self.metrics.on_preempt(st, freed)
        st.status = PREEMPTED
        st.slot = -1
        st.prefill_pos = 0
        st.replay_left = 0
        self._slots[slot] = None
        self._active[slot] = False
        self._dirty = True
        self.stats["preemptions"] += 1
        self.scheduler.requeue(st)

    def _ensure_evicting(self, slot: int, n_logical: int) -> bool:
        """Overcommit-mode `BlockPool.ensure`: on `PoolExhausted`, preempt
        a victim and retry until the demand fits. Returns False when the
        demanding row itself was chosen as the victim (the caller drops
        it from this step's work); True once the blocks are mapped.

        An armed ``_fault_exhaust_once`` (FaultSchedule) raises one
        synthetic `PoolExhausted` before the real ensure — the fault
        flows through the genuine preemption machinery (a real victim is
        evicted), never through a side door that could desynchronize pool
        accounting. With no evictable victim the injected fault is a
        no-op retry (a lone row's real demand always fits: submit()
        bounds it)."""
        injected = self._fault_exhaust_once
        self._fault_exhaust_once = False
        while True:
            try:
                if injected:
                    raise PoolExhausted(
                        "injected fault (FaultSchedule exhaust)")
                if self.pool.ensure(slot, n_logical):
                    self._dirty = True
                return True
            except PoolExhausted:
                victim = self._pick_victim()
                if victim is None:
                    if injected:
                        injected = False
                        continue  # lone row: injected exhaust is a no-op
                    raise  # unreachable: submit() bounds a lone row's need
                injected = False
                vslot, vst = victim
                self._preempt(vslot, vst)
                if vslot == slot:
                    return False

    def _finish_state(self, st: RequestState, reason: str,
                      error: Optional[dict] = None) -> None:
        """The one terminal transition: status from the reason map,
        stamps, live-registry and deadline-count bookkeeping, stats. Both
        retirement paths (slotted `_retire`, unslotted `_finish_queued`)
        funnel through here so no outcome can skip the accounting."""
        st.status = _STATUS_FOR_REASON[reason]
        st.finish_reason = reason
        st.finish_t = self.clock()
        st.error = error
        self._live.pop(st.request_id, None)
        req = st.request
        if req.deadline_s is not None or req.ttft_deadline_s is not None:
            self._deadlined -= 1
        self.stats[_STAT_FOR_REASON[reason]] += 1

    def _retire(self, slot: int, st: RequestState, reason: str,
                horizon_waste: int = 0,
                error: Optional[dict] = None) -> None:
        """Terminal transition for a slotted request (any reason: normal
        finish, timeout, cancel, failure) — the slot and pool blocks are
        freed in the same host step regardless of outcome."""
        self._finish_state(st, reason, error)
        st.slot = -1
        self._slots[slot] = None
        self._active[slot] = False
        if self.pool is not None:
            # free-on-retire: every held block returns to the free list in
            # the same host step; the table row snaps back to TRASH so the
            # retired row's frozen write can't touch a reused block
            self.pool.release(slot)
        self._dirty = True
        self.metrics.on_retire(st, reason, horizon_waste)

    def _finish_queued(self, st: RequestState, reason: str) -> None:
        """Terminal transition for a request that holds no slot (queued or
        preempted): cancellation / deadline expiry before (re)admission.
        The caller has already pulled it from the scheduler heap."""
        self._finish_state(st, reason)
        st.slot = -1
        self.metrics.on_retire(st, reason, 0)

    # ------------------------------------------------------------------
    # robustness: deadlines, cancellation, fault injection
    # ------------------------------------------------------------------

    def _expired(self, st: RequestState, now: float) -> bool:
        """Past its end-to-end deadline, or token-less past its TTFT
        deadline (once the first token streamed, only ``deadline_s`` can
        expire the request)."""
        req = st.request
        if req.deadline_s is not None \
                and now - st.submit_t >= req.deadline_s:
            return True
        return (req.ttft_deadline_s is not None
                and st.first_token_t is None
                and now - st.submit_t >= req.ttft_deadline_s)

    def _ttft_hopeless(self, st: RequestState, now: float) -> bool:
        """Deadline-aware admission: would admitting this queued request
        now blow its TTFT budget anyway? Estimated as the steps its
        prefill needs (one, or the chunk count plus the admission step)
        at the recent step pace (EWMA). Conservative by construction —
        the EWMA starts at 0, so nothing is refused until real steps
        have established a pace."""
        req = st.request
        if req.ttft_deadline_s is None or st.first_token_t is not None:
            return False
        remaining = req.ttft_deadline_s - (now - st.submit_t)
        L = st.prompt_len
        chunk = self.prefill_chunk
        steps = 1 if chunk is None or L <= chunk else -(-L // chunk) + 1
        return steps * self._step_ewma > remaining

    def cancel(self, request_id: int) -> bool:
        """Cancel a live request at any lifecycle stage. Queued and
        preempted states leave the scheduler heap; prefilling/running
        states release their slot and pool blocks. Returns False if the
        id is unknown or already terminal (cancellation races a natural
        finish — losing that race is not an error). Safe between steps
        and from a fault hook: pending bookkeeping for a cancelled row is
        dropped, never applied."""
        st = self._live.get(request_id)
        if st is None:
            return False
        if st.status in (QUEUED, PREEMPTED):
            self.scheduler.remove(st)
            self._finish_queued(st, "cancelled")
        else:  # PREFILLING / RUNNING — it owns a slot
            self._retire(st.slot, st, "cancelled")
        return True

    def live_states(self) -> list[RequestState]:
        """Every non-terminal state the engine knows (queued, preempted,
        prefilling, running) — what a shutdown would have to cancel, and
        what the fault harness picks its victims from."""
        return list(self._live.values())

    def inject_nan(self, slot: int) -> None:
        """Fault injection: poison ``slot``'s logits with NaN on the next
        device step, exercising the FAILED isolation path end to end.
        One-shot — the mask disarms after the step it fires in. The first
        call swaps the compiled step to its poison-carrying variant (one
        retrace); engines that never inject pay nothing."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if self._poison is None:
            self._poison = np.zeros(self.n_slots, bool)
        self._poison[slot] = True
        self._dirty = True

    # ------------------------------------------------------------------
    # convenience driver
    # ------------------------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], *,
                 max_new_tokens: int = 32, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 0.0, seed: Optional[int] = None,
                 eos_id: Optional[int] = None):
        """Submit-all + drain. Returns (outputs, stats) shaped like
        `Server.generate`'s — the engine-backed equivalent of the static
        batcher call, for drop-in use."""
        if seed is None:
            seed = self._auto_seed
            self._auto_seed += len(prompts)
        before = dict(self.stats)  # engines are reusable: report deltas
        t0 = self.clock()
        states = [
            self.submit(Request(
                prompt=tuple(p), max_new_tokens=max_new_tokens,
                eos_id=eos_id,
                sampling=SamplingParams(greedy=greedy,
                                        temperature=temperature,
                                        top_k=top_k, top_p=top_p,
                                        seed=seed + i)))
            for i, p in enumerate(prompts)
        ]
        self.run()
        dt = max(self.clock() - t0, 1e-9)
        outs = [st.output() for st in states]
        n_out = sum(len(o) for o in outs)
        dev = self.stats["device_steps"] - before["device_steps"]
        stats = {
            "decode_tok_s": n_out / dt,
            "steps": self.stats["steps"] - before["steps"],
            "device_steps": dev,
            "transfers": self.stats["transfers"] - before["transfers"],
            "mean_occupancy": ((self.stats["occupancy_sum"]
                                - before["occupancy_sum"]) / max(dev, 1)),
        }
        return outs, stats

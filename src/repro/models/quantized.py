"""Offline model quantization: fp param tree -> ABQ serve-path param tree.

Swaps every quantizable linear leaf for a `QuantLinear` (bit-plane packed
weight + runtime balance vector), leaving norms, embeddings, routers, and the
SSM recurrence parameters in fp — exactly the paper's deployment split
(Fig. 4b: GEMMs run on ABQKernel; softmax/norm/rope stay fp).

Works on *stacked* layer trees by vmapping the per-matrix packer over the
leading layer axis, so a 64-layer model quantizes as one vectorized op per
weight kind.

Calibration results (per-linear balance vector s, clipping α/β, compensation
a·bᵀ) enter through a parallel ``calib`` tree with the same structure; absent
entries fall back to RTN (the paper's no-calibration baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.quantizers import PackedWeight, QuantSpec, pack_weight
from repro.models.layers import QuantLinear

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantizeConfig:
    """Deployment quantization config (the paper's WpAq notation)."""

    w_bits: int = 2
    a_bits: int = 8
    bit_balance: bool = False  # True = the paper's W n* configs
    quantize_lm_head: bool = True
    quantize_moe_experts: bool = True
    group_size: int = 0  # 0 -> per-channel; 128 -> per-group g128
    tensor_par: int = 1  # used to check expert packing divisibility

    @property
    def wspec(self) -> QuantSpec:
        return QuantSpec(
            bits=self.w_bits,
            bit_balance=self.bit_balance,
            granularity="per_group" if self.group_size else "per_channel",
            group_size=self.group_size or 128,
            channel_axis=1,
        )

    def tag(self) -> str:
        star = "*" if self.bit_balance else ""
        return f"W{self.w_bits}{star}A{self.a_bits}"


# quantizable 2-D linear leaf names, by block kind
_ATTN_LINEARS = ("wq", "wk", "wv", "wo")
_MLP_LINEARS = ("w_gate", "w_up", "w_down")
_SSM_LINEARS = ("wz", "wx", "wB", "wC", "wdt", "wout")


def _pack_one(w2d: Array, spec: QuantSpec, calib: Optional[dict]) -> QuantLinear:
    """Quantize a single (K, N) matrix with optional calibration params."""
    w = w2d.astype(jnp.float32)
    inv_s = None
    alpha = beta = None
    comp = None
    if calib is not None:
        s = jnp.exp(calib["log_s"].astype(jnp.float32))  # (K,)
        w = w * s[:, None]
        inv_s = (1.0 / s).astype(jnp.bfloat16)
        alpha = jax.nn.sigmoid(calib["alpha_raw"].astype(jnp.float32))
        beta = jax.nn.sigmoid(calib["beta_raw"].astype(jnp.float32))
        if "comp_a" in calib:
            comp = jnp.outer(
                calib["comp_a"].astype(jnp.float32),
                calib["comp_b"].astype(jnp.float32),
            )
    pw = pack_weight(w, spec, alpha=alpha, beta=beta, compensation=comp)
    return QuantLinear(pw=pw, act_inv_s=inv_s, act_bits=0)  # bits set by caller


def _pack_stacked(w: Array, spec: QuantSpec, a_bits: int,
                  calib: Optional[Any] = None) -> QuantLinear:
    """Pack (L, K, N) stacked weights via vmap; (K, N) packs directly."""
    if w.ndim == 2:
        q = _pack_one(w, spec, calib)
        return QuantLinear(q.pw, q.act_inv_s, a_bits)
    if w.ndim == 3:
        q = jax.vmap(lambda m, c=None: _pack_one(m, spec, None))(w) \
            if calib is None else jax.vmap(
                lambda m, c: _pack_one(m, spec, c))(w, calib)
        return QuantLinear(q.pw, q.act_inv_s, a_bits)
    raise ValueError(f"cannot pack weight of rank {w.ndim}")


def _maybe_calib(calib: Optional[dict], *path):
    node = calib
    for p in path:
        if node is None or p not in node:
            return None
        node = node[p]
    return node


def quantize_block_tree(block_params: dict, qcfg: QuantizeConfig,
                        cfg: ArchConfig, calib: Optional[dict] = None) -> dict:
    """Quantize one (possibly stacked) block param dict."""
    out: dict[str, Any] = {}
    for name, val in block_params.items():
        if name == "attn":
            out[name] = {
                k: (_pack_stacked(v, qcfg.wspec, qcfg.a_bits,
                                  _maybe_calib(calib, name, k))
                    if k in _ATTN_LINEARS else v)
                for k, v in val.items()
            }
        elif name in ("mlp", "shared"):
            out[name] = {
                k: (_pack_stacked(v, qcfg.wspec, qcfg.a_bits,
                                  _maybe_calib(calib, name, k))
                    if k in _MLP_LINEARS else v)
                for k, v in val.items()
            }
        elif name == "ssm":
            out[name] = {
                k: (_pack_stacked(v, qcfg.wspec, qcfg.a_bits,
                                  _maybe_calib(calib, name, k))
                    if k in _SSM_LINEARS else v)
                for k, v in val.items()
            }
        elif name == "moe":
            out[name] = _quantize_moe(val, qcfg, cfg, calib)
        else:
            out[name] = val
    return out


def _expert_ff_packable(cfg: ArchConfig, qcfg: QuantizeConfig) -> bool:
    """Routed-expert down-proj packs its contraction dim (ff) into 32-bit
    words that must still divide by the tensor axis (DESIGN.md §6)."""
    ff = cfg.moe_d_ff or cfg.d_ff
    return ff % (32 * max(qcfg.tensor_par, 1)) == 0


def _quantize_moe(moe_params: dict, qcfg: QuantizeConfig, cfg: ArchConfig,
                  calib: Optional[dict]) -> dict:
    out = dict(moe_params)
    if "shared" in moe_params:
        out["shared"] = {
            k: (_pack_stacked(v, qcfg.wspec, qcfg.a_bits,
                              _maybe_calib(calib, "moe", "shared", k))
                if k in _MLP_LINEARS else v)
            for k, v in moe_params["shared"].items()
        }
    if qcfg.quantize_moe_experts and _expert_ff_packable(cfg, qcfg):
        # (L, E, K, N) or (E, K, N): vmap pack over all leading axes
        for k in ("w_gate", "w_up", "w_down"):
            w = moe_params[k]
            pack = lambda m: _pack_one(m, qcfg.wspec, None)
            for _ in range(w.ndim - 2):
                pack = jax.vmap(pack)
            q = pack(w)
            out[k] = QuantLinear(q.pw, q.act_inv_s, qcfg.a_bits)
    # router always fp (accuracy-critical, tiny)
    return out


def quantize_model(params: dict, cfg: ArchConfig, qcfg: QuantizeConfig,
                   calib: Optional[dict] = None) -> dict:
    """fp param tree -> serve-path tree. ``calib`` mirrors the blocks tree."""
    out: dict[str, Any] = {}
    for name, val in params.items():
        if name in ("blocks", "self_blocks", "cross_blocks"):
            out[name] = quantize_block_tree(
                val, qcfg, cfg, _maybe_calib(calib, name))
        elif name == "shared_attn":
            out[name] = quantize_block_tree(
                val, qcfg, cfg, _maybe_calib(calib, name))
        elif name == "lm_head" and qcfg.quantize_lm_head:
            out[name] = _pack_stacked(val, qcfg.wspec, qcfg.a_bits)
        elif name == "heads" and qcfg.quantize_lm_head:
            # audio: (n_cb, D, V)
            q = jax.vmap(lambda m: _pack_one(m, qcfg.wspec, None))(val)
            out[name] = QuantLinear(q.pw, q.act_inv_s, qcfg.a_bits)
        else:
            out[name] = val
    return out


def quantized_bytes(tree) -> int:
    """Total weight bytes of a (possibly quantized) param tree."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * leaf.dtype.itemsize
    return total

"""Physical PartitionSpecs for every param / cache / batch tree.

Path-based rules (MaxText-style logical->physical): the weight's role is
identified by its leaf name, the stacked-layer axis by its subtree root.
Quantized trees (QuantLinear leaves) inherit the base weight's rule with the
packed-word contraction dim.

fsdp = ("pod","data")-composed axis (weight rows / ZeRO-3);
tensor = "model" (heads / ff / vocab / experts-ff).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.dist.sharding import ShardingRules, axis_size

# subtree roots whose children are stacked on a leading layer axis
_STACKED_ROOTS = {"blocks", "self_blocks", "cross_blocks"}

# leaf-name roles
_ROW_MAJOR = {"wq", "wk", "wv", "w_gate", "w_up", "wz", "wx", "wdt"}  # (d, X)
_ROW_MAJOR_SMALL = {"wB", "wC"}  # (d, small) — keep out dim replicated
_COL_MAJOR = {"wo", "w_down", "wout"}  # (X, d)
_REPLICATED = {
    "attn_norm", "mlp_norm", "norm", "final_norm", "q_norm", "k_norm",
    "router", "dt_bias", "A_log", "D", "gate_attn", "gate_mlp",
    "conv_B", "conv_C",
}


def _spec_for_leaf(path_keys: list[str], shape, rules: ShardingRules,
                   mesh: Mesh) -> P:
    fsdp, tp = rules.fsdp, rules.tensor
    stacked = path_keys[0] in _STACKED_ROOTS
    prefix = (None,) * (1 if stacked else 0)

    # identify the innermost "weight name" — for QuantLinear leaves the path
    # ends with .../<wname>/(pw/planes | pw/scale | pw/zero_point | act_inv_s)
    quant_part = None
    name = path_keys[-1]
    if name in ("planes", "scale", "zero_point"):
        quant_part = name
        wname = path_keys[-3]  # <wname>/pw/<part>
    elif name == "act_inv_s":
        quant_part = name
        wname = path_keys[-2]
    else:
        wname = name

    is_expert = "moe" in path_keys and wname in ("w_gate", "w_up", "w_down") \
        and "shared" not in path_keys
    ndim = len(shape)
    base = ndim - len(prefix)  # dims excluding the stacked-layer axis

    def fits(dim_size, ax):
        return ax is not None and dim_size % max(axis_size(mesh, ax), 1) == 0

    if quant_part is None:
        if wname in _REPLICATED or base <= 1:
            return P(*(prefix + (None,) * base))
        if is_expert:  # (E, K, N) under the stacked prefix
            if wname == "w_down":
                sp = (None,
                      tp if fits(shape[-2], tp) else None,
                      fsdp if fits(shape[-1], fsdp) else None)
            else:
                sp = (None,
                      fsdp if fits(shape[-2], fsdp) else None,
                      tp if fits(shape[-1], tp) else None)
            return P(*(prefix + sp))
        if wname == "conv_x":
            return P(*(prefix + (None, tp if fits(shape[-1], tp) else None)))
        if wname == "embed":
            if base == 3:  # audio codebook embeds (n_cb, V, D)
                return P(None if False else None,
                         tp if fits(shape[-2], tp) else None,
                         fsdp if fits(shape[-1], fsdp) else None)
            return P(tp if fits(shape[-2] if base > 2 else shape[0], tp) else None,
                     fsdp if fits(shape[-1], fsdp) else None)
        if wname == "lm_head":
            return P(fsdp if fits(shape[0], fsdp) else None,
                     tp if fits(shape[1], tp) else None)
        if wname == "heads":  # audio (n_cb, D, V)
            return P(None,
                     fsdp if fits(shape[-2], fsdp) else None,
                     tp if fits(shape[-1], tp) else None)
        if wname in _ROW_MAJOR:
            sp = (fsdp if fits(shape[-2], fsdp) else None,
                  tp if fits(shape[-1], tp) else None)
            return P(*(prefix + sp))
        if wname in _ROW_MAJOR_SMALL:
            sp = (fsdp if fits(shape[-2], fsdp) else None, None)
            return P(*(prefix + sp))
        if wname in _COL_MAJOR:
            sp = (tp if fits(shape[-2], tp) else None,
                  fsdp if fits(shape[-1], fsdp) else None)
            return P(*(prefix + sp))
        # default: replicate
        return P(*((None,) * ndim))

    # ---- quantized leaves ----
    # planes: (..., P, Kw, N) — shard only (Kw, N); scale/zp: (..., 1, N) —
    # shard only N; act_inv_s: (..., K) replicated (small).
    col = wname in _COL_MAJOR or (is_expert and wname == "w_down")
    if quant_part == "planes":
        lead = (None,) * (ndim - 2)
        if col:  # contraction (rows) was tensor-sharded
            return P(*(lead + (tp if fits(shape[-2], tp) else None,
                               fsdp if fits(shape[-1], fsdp) else None)))
        return P(*(lead + (fsdp if fits(shape[-2], fsdp) else None,
                           tp if fits(shape[-1], tp) else None)))
    if quant_part in ("scale", "zero_point"):
        lead = (None,) * (ndim - 1)
        if col:
            return P(*(lead + (fsdp if fits(shape[-1], fsdp) else None,)))
        return P(*(lead + (tp if fits(shape[-1], tp) else None,)))
    # act_inv_s (K,): replicate (small)
    return P(*((None,) * ndim))


def param_pspecs(params: Any, cfg: ArchConfig, rules: ShardingRules,
                 mesh: Mesh) -> Any:
    """PartitionSpec tree matching ``params``."""
    rules = rules.resolve(mesh)

    def walk(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
        return _spec_for_leaf(keys, leaf.shape, rules, mesh)

    return jax.tree_util.tree_map_with_path(walk, params)


def param_shardings(params, cfg, rules, mesh) -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(params, cfg, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(batch: dict, rules: ShardingRules, mesh: Mesh) -> dict:
    rules = rules.resolve(mesh)
    bt = rules.batch

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if bt is not None and leaf.shape[0] % max(axis_size(mesh, bt), 1) == 0:
            return P(*((bt,) + (None,) * (leaf.ndim - 1)))
        return P(*((None,) * leaf.ndim))

    return jax.tree.map(spec, batch)


def cache_pspecs(cache: dict, cfg: ArchConfig, rules: ShardingRules,
                 mesh: Mesh) -> dict:
    """Decode-cache specs: batch over dp when divisible, kv-heads / d_inner
    over tensor. Cache layout (lm.init_cache, attention-native):
      attn/cross: values (L, B, KVH, S, hd) + scales (L, B, KVH, S)
      ssm: conv_x (L,B,W-1,din) conv_B/C (L,B,W-1,ns) state (L,B,H,ns,hd)
      pos: scalar
    """
    rules = rules.resolve(mesh)
    bt, tp = rules.batch, rules.tensor

    def fits(n, ax):
        return ax is not None and n % max(axis_size(mesh, ax), 1) == 0

    def spec_path(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        if leaf.ndim == 0:
            return P()
        b_ax = bt if fits(leaf.shape[1], bt) else None
        if "attn" in keys or "cross" in keys:
            head_ax = tp if fits(leaf.shape[2], tp) else None
            if leaf.ndim == 4:  # scales (L, B, KVH, S)
                return P(None, b_ax, head_ax, None)
            return P(None, b_ax, head_ax, None, None)
        if "ssm" in keys:
            name = keys[-1]
            if name == "conv_x":
                return P(None, b_ax, None, tp if fits(leaf.shape[-1], tp) else None)
            if name == "state":
                return P(None, b_ax, tp if fits(leaf.shape[2], tp) else None,
                         None, None)
            return P(*((None, b_ax) + (None,) * (leaf.ndim - 2)))
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_path, cache)

"""Top-k routed MoE with optional shared experts (grok-1, qwen2-moe).

Dispatch is sort-free capacity-based gather/scatter executed **locally per
data shard** inside a `shard_map` (DESIGN.md §4): tokens never cross the data
axis (no all-to-all in the baseline — recorded as a perf-iteration option);
the expert FFN contraction dim (d_ff) is tensor-parallel, so the only
collective inside the layer is the psum over the model axis after down-proj.

Routing math (per shard):
  logits -> softmax -> top-k -> position-within-expert via counts cumsum
  -> scatter into (E, C, d) buffers (capacity-dropped) -> batched expert
  einsum -> weighted scatter-add back to tokens.

The router runs in fp32 (accuracy-critical, like the paper keeping softmax
fp); expert GEMMs run through `apply_linear`, so the ABQ serve path quantizes
them like any other linear.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ArchConfig
from repro.core.quantizers import PackedWeight
from repro.kernels import ops as kops
from repro.models.layers import QuantLinear, activation, apply_linear, dense_init

Array = jax.Array


# ---------------------------------------------------------------------------
# expert matmul: dense einsum or vmapped ABQ bit-plane GEMM
# ---------------------------------------------------------------------------


def _expert_matmul(buf: Array, w: Any, *, backend: str = "auto",
                   interpret: bool = False) -> Array:
    """(E, C, K) x per-expert weight -> (E, C, N).

    Quantized experts run the paper's kernel per expert (vmapped); the
    per-shard activation quantization when K is tensor-sharded acts as
    shard-group quantization (exact partial dequant + psum, DESIGN.md §4).
    """
    if isinstance(w, QuantLinear):
        planes, scale, zp = w.pw.planes, w.pw.scale, w.pw.zero_point
        k_local = planes.shape[-2] * 32
        bits = w.pw.bits

        def one(buf_e, planes_e, scale_e, zp_e, inv_s_e=None):
            x = buf_e if inv_s_e is None else buf_e * inv_s_e
            xq, xs = kops.act_quant(x, bits=w.act_bits, backend=backend,
                                    interpret=interpret)
            pw_e = PackedWeight(planes_e, scale_e, zp_e, bits, k_local)
            return kops.abq_matmul(xq, xs, pw_e, out_dtype=buf_e.dtype,
                                   backend=backend, interpret=interpret)

        if w.act_inv_s is None:
            return jax.vmap(one)(buf, planes, scale, zp)
        return jax.vmap(one)(buf, planes, scale, zp, w.act_inv_s)
    return jnp.einsum("eck,ekn->ecn", buf, w.astype(buf.dtype))


def _wspec(w: Any, role: str, tp) -> Any:
    """shard_map in_specs for an expert weight (dense or QuantLinear).

    role 'up': contraction d (unsharded), output ff (tensor-sharded);
    role 'down': contraction ff (tensor-sharded words), output d.
    """
    def leaf_spec(leaf):
        if leaf.ndim == 4:  # planes (E, P, Kw, N)
            return P(None, None, None, tp) if role == "up" else P(None, None, tp, None)
        if leaf.ndim == 3 and leaf.shape[1] == 1:  # scale/zp (E, 1, N)
            return P(None, None, tp) if role == "up" else P(None, None, None)
        if leaf.ndim == 3:  # dense (E, K, N)
            return P(None, None, tp) if role == "up" else P(None, tp, None)
        if leaf.ndim == 2:  # act_inv_s (E, K)
            return P(None, None) if role == "up" else P(None, tp)
        raise ValueError(f"unexpected expert weight leaf rank {leaf.ndim}")

    return jax.tree.map(leaf_spec, w)


def init_moe_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * d**-0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32) * ff**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        sff = (cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, sff), dtype),
            "w_up": dense_init(ks[5], (d, sff), dtype),
            "w_down": dense_init(ks[4], (sff, d), dtype),
        }
    return p


def _route(router_w: Array, x_flat: Array, top_k: int):
    """fp32 router: returns (weights (T,k), experts (T,k), aux load loss)."""
    logits = x_flat.astype(jnp.float32) @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )
    # Switch-style load-balancing aux loss
    e = router_w.shape[1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux = e * jnp.sum(me * ce)
    return weights, experts, aux


def _dispatch_compute_combine(
    x_flat: Array,  # (T, d) local tokens
    weights: Array,  # (T, k)
    experts: Array,  # (T, k)
    w_gate: Any,  # (E, d, ff_local) dense or QuantLinear
    w_up: Any,
    w_down: Any,  # (E, ff_local, d) dense or QuantLinear
    capacity: int,
    act: str,
    n_experts: int,
):
    t, d = x_flat.shape
    e = n_experts
    k = experts.shape[1]
    flat_e = experts.reshape(-1)  # (T*k,)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    # position of each assignment within its expert (first-come priority)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    valid = pos < capacity
    safe_pos = jnp.where(valid, pos, 0)

    buf = jnp.zeros((e, capacity, d), x_flat.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        x_flat[tok_idx] * valid[:, None].astype(x_flat.dtype),
        mode="drop",
    )
    # expert FFN: (E, C, d) x (E, d, ff) -> (E, C, ff)
    g = _expert_matmul(buf, w_gate)
    u = _expert_matmul(buf, w_up)
    h = activation(g, act) * u
    y_buf = _expert_matmul(h, w_down)
    # combine
    gathered = y_buf[flat_e, safe_pos]  # (T*k, d)
    contrib = gathered * (weights.reshape(-1)[:, None] * valid[:, None]).astype(
        gathered.dtype
    )
    y = jnp.zeros_like(x_flat)
    y = y.at[tok_idx].add(contrib)
    return y


def moe_ffn(
    params: dict,
    x: Array,  # (B, S, d)
    cfg: ArchConfig,
    *,
    mesh: Optional[Mesh] = None,
    dp_axes: Any = ("pod", "data"),
    tp_axis: str = "model",
    backend: str = "auto",
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Routed-experts FFN (+ shared experts). Returns (y, aux_loss)."""
    b, s, d = x.shape
    top_k = cfg.top_k

    def local_moe(xl, router_w, w_gate, w_up, w_down, *, tp_size: int,
                  dp: tuple = ()):
        bl, sl = xl.shape[0], xl.shape[1]
        t_local = bl * sl
        cap = max(
            top_k,
            int(math.ceil(t_local * top_k / cfg.n_experts * cfg.capacity_factor)),
        )
        x_flat = xl.reshape(t_local, d)
        weights, experts, aux = _route(router_w, x_flat, top_k)
        y = _dispatch_compute_combine(
            x_flat, weights, experts, w_gate, w_up, w_down, cap, cfg.act,
            cfg.n_experts,
        )
        if tp_size > 1:
            y = jax.lax.psum(y, tp_axis)
            aux = jax.lax.pmean(aux, tp_axis)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(bl, sl, d), aux

    if mesh is None or mesh.empty or mesh.size == 1:
        y, aux = local_moe(
            x,
            params["router"],
            params["w_gate"],
            params["w_up"],
            params["w_down"],
            tp_size=1,
        )
    else:
        dp = tuple(a for a in (dp_axes if isinstance(dp_axes, tuple) else (dp_axes,))
                   if a in mesh.axis_names)
        tp = tp_axis if tp_axis in mesh.axis_names else None
        tp_size = mesh.shape[tp] if tp else 1
        in_specs = (
            P(dp, None, None),                # x: batch-sharded, full seq/d
            P(None, None),                    # router replicated
            _wspec(params["w_gate"], "up", tp),   # experts: ff tensor-parallel
            _wspec(params["w_up"], "up", tp),
            _wspec(params["w_down"], "down", tp),
        )
        out_specs = (P(dp, None, None), P())
        from repro.dist.compat import shard_map

        y, aux = shard_map(
            partial(local_moe, tp_size=tp_size, dp=dp),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check=False,
        )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    if cfg.n_shared_experts:
        sh = params["shared"]
        g = apply_linear(x, sh["w_gate"], backend=backend, interpret=interpret)
        u = apply_linear(x, sh["w_up"], backend=backend, interpret=interpret)
        hsh = activation(g, cfg.act) * u
        y = y + apply_linear(hsh, sh["w_down"], backend=backend, interpret=interpret)
    return y, aux

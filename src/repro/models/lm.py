"""The language model: init / train / prefill / decode for all 6 families.

Families (DESIGN.md §6):
  dense   — uniform stack of GQA+GLU blocks (qwen3, gemma, stablelm, minitron,
            llama-7b)
  moe     — dense blocks with routed-expert FFN (grok-1, qwen2-moe)
  ssm     — uniform Mamba2/SSD stack (mamba2-2.7b)
  hybrid  — Mamba2 stack with a *shared* attention block applied every N
            layers (zamba2)
  vlm     — groups of (k−1 self layers + 1 gated cross-attn layer) attending
            to stub image embeddings (llama-3.2-vision)
  audio   — dense stack over summed EnCodec codebook embeddings with one
            output head per codebook (musicgen)

Layer params are stacked on a leading axis and driven by lax.scan (compile
time independent of depth); training wraps the scan body in jax.checkpoint.
The same block code runs fp (training) and ABQ-quantized (serving) — the
quantized param tree just swaps linear leaves for QuantLinear containers.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import attention as attn_mod
from repro.models import blocks as B
from repro.models import ssm as ssm_mod
from repro.models.blocks import ModelContext
from repro.models.layers import apply_linear, embed_init, dense_init, index_linear, rms_norm
from repro.models.loss import logits_last_token, xent_chunked

Array = jax.Array


def _scoped(name: str):
    """Wrap a forward fn in `jax.named_scope` so profiler traces
    (`REPRO_TRACE_DIR`, see `serving.engine.Engine.run`) attribute device
    time to the serving phase that issued it. Naming metadata only — the
    lowered math, and therefore every token, is bitwise unchanged."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with jax.named_scope(name):
                return fn(*args, **kwargs)
        return wrapper
    return deco


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab
    d = cfg.d_model
    params: dict[str, Any] = {"final_norm": jnp.ones((d,), dtype)}

    if cfg.family == "audio":
        params["embed"] = (
            jax.random.normal(ks[0], (cfg.n_codebooks, vp, d), jnp.float32) * 0.02
        ).astype(dtype)
        params["heads"] = (
            jax.random.normal(ks[1], (cfg.n_codebooks, d, vp), jnp.float32)
            * d**-0.5
        ).astype(dtype)
    else:
        params["embed"] = (
            jax.random.normal(ks[0], (vp, d), jnp.float32) * 0.02
        ).astype(dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], (d, vp), dtype)

    if cfg.family in ("dense", "audio"):
        params["blocks"] = _stack_init(
            lambda k: B.init_dense_block(k, cfg, dtype), ks[2], cfg.n_layers
        )
    elif cfg.family == "moe":
        params["blocks"] = _stack_init(
            lambda k: B.init_moe_block(k, cfg, dtype), ks[2], cfg.n_layers
        )
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: B.init_ssm_block(k, cfg, dtype), ks[2], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: B.init_ssm_block(k, cfg, dtype), ks[2], cfg.n_layers
        )
        params["shared_attn"] = B.init_dense_block(ks[3], cfg, dtype)
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        n_groups = cfg.n_layers // every
        n_self = n_groups * (every - 1)
        params["self_blocks"] = _stack_init(
            lambda k: B.init_dense_block(k, cfg, dtype), ks[2], n_self
        )
        params["cross_blocks"] = _stack_init(
            lambda k: B.init_cross_block(k, cfg, dtype), ks[3], n_groups
        )
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params


# ---------------------------------------------------------------------------
# embedding / head helpers
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens: Array, cfg: ArchConfig, ctx: ModelContext) -> Array:
    if cfg.family == "audio":
        # tokens: (B, S, n_codebooks) -> sum of codebook embeddings
        h = jnp.zeros(tokens.shape[:2] + (cfg.d_model,),
                      params["embed"].dtype)
        for cb in range(cfg.n_codebooks):
            h = h + jnp.take(params["embed"][cb], tokens[..., cb], axis=0)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    return ctx.shard(h, "batch", "seq", None)


def lm_head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        w = params["embed"]
        return w.T if hasattr(w, "T") else w
    return params["lm_head"]


# ---------------------------------------------------------------------------
# stacks (train / full-sequence forward)
# ---------------------------------------------------------------------------


def _scan_stack(stacked_params, x, ctx: ModelContext, body_fn, extra=None):
    """lax.scan over stacked layer params; body returns new carry."""

    def body(carry, layer_params):
        if extra is None:
            y = body_fn(layer_params, carry, ctx)
        else:
            y = body_fn(layer_params, carry, extra, ctx)
        return y, None

    if ctx.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, stacked_params, unroll=ctx.unroll)
    return x


def _reshape_groups(tree, n_groups: int, group: int):
    return jax.tree.map(
        lambda a: a[: n_groups * group].reshape((n_groups, group) + a.shape[1:]),
        tree,
    )


def _tail(tree, start: int):
    return jax.tree.map(lambda a: a[start:], tree)


def forward_hidden(params, tokens, cfg: ArchConfig, ctx: ModelContext,
                   image_embeds: Optional[Array] = None) -> tuple[Array, Array]:
    """Token ids -> final hidden states (pre-head). Returns (h, aux_loss)."""
    h = embed_tokens(params, tokens, cfg, ctx)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "audio"):
        h = _scan_stack(params["blocks"], h, ctx,
                        lambda p, x, c: B.dense_block(p, x, c)[0])
    elif cfg.family == "moe":
        def body(carry, layer_params):
            x, a = carry
            x, _, aux_l = B.moe_block(layer_params, x, ctx)
            return (x, a + aux_l), None

        body_fn = jax.checkpoint(body) if ctx.remat else body
        (h, aux), _ = jax.lax.scan(body_fn, (h, aux), params["blocks"],
                                   unroll=ctx.unroll)
    elif cfg.family == "ssm":
        h = _scan_stack(params["blocks"], h, ctx, B.ssm_block)
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        rem = cfg.n_layers - n_groups * every
        grouped = _reshape_groups(params["blocks"], n_groups, every)
        shared = params["shared_attn"]

        def group_body(carry, group_params):
            x = carry
            x = _scan_stack(group_params, x, dataclass_no_remat(ctx), B.ssm_block)
            x, _ = B.dense_block(shared, x, ctx)
            return x, None

        gb = jax.checkpoint(group_body) if ctx.remat else group_body
        h, _ = jax.lax.scan(gb, h, grouped, unroll=ctx.unroll)
        if rem:
            h = _scan_stack(_tail(params["blocks"], n_groups * every), h, ctx,
                            B.ssm_block)
    elif cfg.family == "vlm":
        assert image_embeds is not None, "vlm needs image embeddings (stub frontend)"
        every = cfg.cross_attn_every
        n_groups = cfg.n_layers // every
        self_grouped = _reshape_groups(params["self_blocks"], n_groups, every - 1)

        def group_body(carry, xs):
            x = carry
            sp, cp = xs
            x = _scan_stack(sp, x, dataclass_no_remat(ctx),
                            lambda p, y, c: B.dense_block(p, y, c)[0])
            x = B.cross_block(cp, x, image_embeds, ctx)
            return x, None

        gb = jax.checkpoint(group_body) if ctx.remat else group_body
        h, _ = jax.lax.scan(gb, h, (self_grouped, params["cross_blocks"]),
                            unroll=ctx.unroll)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def dataclass_no_remat(ctx: ModelContext) -> ModelContext:
    import dataclasses

    return dataclasses.replace(ctx, remat=False)


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch: dict, cfg: ArchConfig, ctx: ModelContext,
            n_loss_chunks: int = 8) -> tuple[Array, dict]:
    tokens = batch["tokens"]
    labels = batch["labels"]
    h, aux = forward_hidden(params, tokens, cfg, ctx,
                            image_embeds=batch.get("image_embeds"))
    if cfg.family == "audio":
        # mean NLL over the n_codebooks heads
        total = jnp.zeros((), jnp.float32)
        for cb in range(cfg.n_codebooks):
            total = total + xent_chunked(
                h, index_linear(params["heads"], cb), labels[..., cb],
                shard=ctx.shard, n_chunks=n_loss_chunks, unroll=ctx.unroll,
            )
        loss = total / cfg.n_codebooks
    else:
        loss = xent_chunked(
            h, lm_head_weight(params, cfg), labels,
            shard=ctx.shard, n_chunks=n_loss_chunks, unroll=ctx.unroll,
        )
    metrics = {"loss": loss, "aux_loss": aux}
    if cfg.family == "moe":
        loss = loss + 0.01 * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill
# ---------------------------------------------------------------------------


def _attn_stack_prefill(stacked_params, h, ctx):
    """Scan dense/moe blocks, emitting quantized KV per layer."""

    def body(carry, layer_params):
        x = carry
        x, kv = B.dense_block_prefill(layer_params, x, ctx)
        return x, kv

    h, kvs = jax.lax.scan(body, h, stacked_params, unroll=ctx.unroll)
    return h, {"k": kvs[0], "k_scale": kvs[1], "v": kvs[2], "v_scale": kvs[3]}


def _pad_cache(cache_kv: dict, max_len: int, seq_axis: int = 3) -> dict:
    """Grow prefill KV to the decode cache capacity (zero-padded).

    Attention-native layout: values (L,B,KVH,S,D) and scales (L,B,KVH,S) —
    the sequence axis is 3 for both."""

    def pad(a):
        pad_widths = [(0, 0)] * a.ndim
        pad_widths[seq_axis] = (0, max_len - a.shape[seq_axis])
        return jnp.pad(a, pad_widths)

    return jax.tree.map(pad, cache_kv)


@_scoped("repro.prefill")
def prefill(params, tokens, cfg: ArchConfig, ctx: ModelContext, *,
            max_len: int, image_embeds: Optional[Array] = None,
            last_pos: Optional[Array] = None):
    """Run the prompt, build the decode cache. Returns (last_logits, cache).

    ``last_pos`` (traced scalar, or (B,) vector for per-row prompt lengths)
    selects which position's logits to return; default is the final one.
    The serving engine right-pads prompts to a bucket length (amortizing
    jit compiles across prompt lengths) and passes the true last-token
    index here — causality keeps the valid prefix's hidden states and KV
    bitwise independent of the padded tail, so a bucketed prefill is exact.
    """
    b, s = tokens.shape[0], tokens.shape[1]
    h = embed_tokens(params, tokens, cfg, ctx)
    cache: dict[str, Any] = {"pos": jnp.asarray(s, jnp.int32)}

    if cfg.family in ("dense", "moe", "audio"):
        h, kv = _attn_stack_prefill(params["blocks"], h, ctx)
        cache["attn"] = _pad_cache(kv, max_len)
    elif cfg.family == "ssm":
        # run full-seq SSD, then recompute final states via a short decode
        # replay of the last conv window; cheaper: use ssd scan's final state.
        h, ssm_cache = _ssm_stack_prefill(params["blocks"], h, cfg, ctx)
        cache["ssm"] = ssm_cache
    elif cfg.family == "hybrid":
        h, ssm_cache, attn_cache = _hybrid_prefill(params, h, cfg, ctx, max_len)
        cache["ssm"] = ssm_cache
        cache["attn"] = attn_cache
    elif cfg.family == "vlm":
        assert image_embeds is not None
        h, self_cache, cross_cache = _vlm_prefill(params, h, image_embeds, cfg,
                                                  ctx, max_len)
        cache["attn"] = self_cache
        cache["cross"] = cross_cache
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if last_pos is None:
        h_last = h[:, -1:]
    elif jnp.ndim(last_pos) == 0:
        h_last = jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1)
    else:
        h_last = jnp.take_along_axis(h, last_pos[:, None, None], axis=1)
    if cfg.family == "audio":
        logits = jnp.stack(
            [logits_last_token(h_last, index_linear(params["heads"], cb), ctx.shard)
             for cb in range(cfg.n_codebooks)],
            axis=-2,
        )  # (B, 1, n_cb, V)
    else:
        logits = logits_last_token(h_last, lm_head_weight(params, cfg), ctx.shard)
    return logits, cache


def _ssm_stack_prefill(stacked_params, h, cfg, ctx):
    """SSD forward that also returns per-layer final (conv, state) caches.

    We reuse ssm_forward for the hidden stream; final states come from a
    dedicated pass inside ssm.py would double compute — instead we exploit
    that the SSD scan's carried state at the last chunk IS the decode state.
    For simplicity and correctness we recompute conv tails + final state with
    a cheap targeted helper.
    """

    def body(carry, layer_params):
        x = carry
        xn = rms_norm(x, layer_params["norm"], cfg.norm_eps)
        y, st = ssm_mod.ssm_forward_with_state(layer_params["ssm"], xn, cfg,
                                               shard=ctx.shard, **ctx.kw)
        return x + y, st

    h, states = jax.lax.scan(body, h, stacked_params, unroll=ctx.unroll)
    return h, states


def _hybrid_prefill(params, h, cfg, ctx, max_len):
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    rem = cfg.n_layers - n_groups * every
    grouped = _reshape_groups(params["blocks"], n_groups, every)
    shared = params["shared_attn"]

    def group_body(carry, group_params):
        x = carry

        def inner(c, lp):
            xn = rms_norm(c, lp["norm"], cfg.norm_eps)
            y, st = ssm_mod.ssm_forward_with_state(lp["ssm"], xn, cfg,
                                                   shard=ctx.shard, **ctx.kw)
            return c + y, st

        x, states = jax.lax.scan(inner, x, group_params, unroll=ctx.unroll)
        xn = rms_norm(x, shared["attn_norm"], cfg.norm_eps)
        a, kv = attn_mod.attend_prefill(shared["attn"], xn, cfg,
                                        shard=ctx.shard, **ctx.loop_kw,
                                        **ctx.kw)
        x = x + a
        hn = rms_norm(x, shared["mlp_norm"], cfg.norm_eps)
        from repro.models.layers import glu_mlp

        x = x + glu_mlp(shared["mlp"], hn, cfg.act, shard=ctx.shard, **ctx.kw)
        return x, (states, kv)

    h, (ssm_states, kvs) = jax.lax.scan(group_body, h, grouped,
                                        unroll=ctx.unroll)
    # ssm_states leaves: (n_groups, every, B, ...) -> flatten to (L_used, ...)
    ssm_cache = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), ssm_states
    )
    if rem:
        def inner(c, lp):
            xn = rms_norm(c, lp["norm"], cfg.norm_eps)
            y, st = ssm_mod.ssm_forward_with_state(lp["ssm"], xn, cfg,
                                                   shard=ctx.shard, **ctx.kw)
            return c + y, st

        h, tail_states = jax.lax.scan(inner, h,
                                      _tail(params["blocks"], n_groups * every),
                                      unroll=ctx.unroll)
        ssm_cache = jax.tree.map(
            lambda a, b_: jnp.concatenate([a, b_], axis=0), ssm_cache, tail_states
        )
    attn_cache = _pad_cache(
        {"k": kvs[0], "k_scale": kvs[1], "v": kvs[2], "v_scale": kvs[3]}, max_len
    )
    return h, ssm_cache, attn_cache


def _vlm_prefill(params, h, image_embeds, cfg, ctx, max_len):
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    self_grouped = _reshape_groups(params["self_blocks"], n_groups, every - 1)

    def group_body(carry, xs):
        x = carry
        sp, cp = xs

        def inner(c, lp):
            c2, kv = B.dense_block_prefill(lp, c, ctx)
            return c2, kv

        x, kvs = jax.lax.scan(inner, x, sp, unroll=ctx.unroll)
        # cross block: cache image K/V (quantized) once
        xn = rms_norm(x, cp["attn_norm"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        bt = image_embeds.shape[0]
        t = image_embeds.shape[1]
        k = apply_linear(image_embeds, cp["attn"]["wk"], **ctx.kw).reshape(
            bt, t, cfg.n_kv_heads, hd)
        v = apply_linear(image_embeds, cp["attn"]["wv"], **ctx.kw).reshape(
            bt, t, cfg.n_kv_heads, hd)
        kq, ks_, vq, vs_ = attn_mod.quantize_kv_cached(k, v)
        x = B.cross_block(cp, x, image_embeds, ctx)
        return x, (kvs, (kq, ks_, vq, vs_))

    h, (self_kvs, cross_kvs) = jax.lax.scan(
        group_body, h, (self_grouped, params["cross_blocks"]),
        unroll=ctx.unroll,
    )
    self_cache = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                              {"k": self_kvs[0], "k_scale": self_kvs[1],
                               "v": self_kvs[2], "v_scale": self_kvs[3]})
    self_cache = _pad_cache(self_cache, max_len)
    cross_cache = {"k": cross_kvs[0], "k_scale": cross_kvs[1],
                   "v": cross_kvs[2], "v_scale": cross_kvs[3]}
    return h, self_cache, cross_cache


# ---------------------------------------------------------------------------
# serving: decode step
# ---------------------------------------------------------------------------


@_scoped("repro.decode_step")
def decode_step(params, cache: dict, tokens: Array, cfg: ArchConfig,
                ctx: ModelContext, *, block_tables: Optional[Array] = None):
    """One token for every sequence. tokens: (B, 1) (audio: (B, 1, n_cb)).

    ``cache["pos"]`` may be a scalar (lockstep: all rows at the same
    position) or a (B,) vector (the continuous-batching engine: every cache
    row — "slot" — decodes at its own position/length). The vector form is
    what makes ragged batches free: RoPE, the KV write index, and the
    decode-attention valid length are all per-row downstream of it.

    ``block_tables`` ((B, max_blocks) int32) switches the attention cache
    to the paged BlockPool layout (dense/moe only): the tables are shared
    by every layer (the layer scan closes over them; only the pool leaves
    are scanned) and every KV read/write resolves through them — see
    `attention.attend_decode`.

    Returns (logits, new_cache). This is the function the decode_32k /
    long_500k dry-run cells lower — the ABQ regime.
    """
    if block_tables is not None and cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged KV decode needs a pos-indexed pure-attention cache "
            f"(dense/moe), got {cfg.family!r}")
    pos = cache["pos"]
    h = embed_tokens(params, tokens, cfg, ctx)
    new_cache: dict[str, Any] = {"pos": pos + 1}

    if cfg.family in ("dense", "moe", "audio"):
        def body(carry, xs):
            x = carry
            lp, lc = xs
            x, nc = B.dense_block_decode(lp, x, lc, pos, ctx,
                                         block_tables=block_tables)
            return x, nc

        h, updated = jax.lax.scan(body, h, (params["blocks"], cache["attn"]),
                                  unroll=ctx.unroll)
        new_cache["attn"] = updated
    elif cfg.family == "ssm":
        def body(carry, xs):
            x = carry
            lp, lc = xs
            x, nc = B.ssm_block_decode(lp, x, lc, ctx)
            return x, nc

        h, updated = jax.lax.scan(body, h, (params["blocks"], cache["ssm"]),
                                  unroll=ctx.unroll)
        new_cache["ssm"] = updated
    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, h, cache, pos, cfg, ctx, new_cache)
    elif cfg.family == "vlm":
        h, new_cache = _vlm_decode(params, h, cache, pos, cfg, ctx, new_cache)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.family == "audio":
        logits = jnp.stack(
            [logits_last_token(h, index_linear(params["heads"], cb), ctx.shard)
             for cb in range(cfg.n_codebooks)],
            axis=-2,
        )
    else:
        logits = logits_last_token(h, lm_head_weight(params, cfg), ctx.shard)
    return logits, new_cache


def _hybrid_decode(params, h, cache, pos, cfg, ctx, new_cache):
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    rem = cfg.n_layers - n_groups * every
    grouped = _reshape_groups(params["blocks"], n_groups, every)
    ssm_grouped = jax.tree.map(
        lambda a: a[: n_groups * every].reshape((n_groups, every) + a.shape[1:]),
        cache["ssm"],
    )
    shared = params["shared_attn"]

    def group_body(carry, xs):
        x = carry
        gp, gc, ac = xs

        def inner(c, lp_lc):
            lp, lc = lp_lc
            return B.ssm_block_decode(lp, c, lc, ctx)

        x, new_ssm = jax.lax.scan(inner, x, (gp, gc), unroll=ctx.unroll)
        x, new_attn = B.dense_block_decode(shared, x, ac, pos, ctx)
        return x, (new_ssm, new_attn)

    h, (new_ssm_g, new_attn) = jax.lax.scan(
        group_body, h, (grouped, ssm_grouped, cache["attn"]),
        unroll=ctx.unroll,
    )
    new_ssm = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), new_ssm_g)
    if rem:
        tail_cache = jax.tree.map(lambda a: a[n_groups * every:], cache["ssm"])

        def inner(c, lp_lc):
            lp, lc = lp_lc
            return B.ssm_block_decode(lp, c, lc, ctx)

        h, new_tail = jax.lax.scan(
            inner, h, (_tail(params["blocks"], n_groups * every), tail_cache),
            unroll=ctx.unroll,
        )
        new_ssm = jax.tree.map(
            lambda a, b_: jnp.concatenate([a, b_], axis=0), new_ssm, new_tail
        )
    new_cache["ssm"] = new_ssm
    new_cache["attn"] = new_attn
    return h, new_cache


def _vlm_decode(params, h, cache, pos, cfg, ctx, new_cache):
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    self_grouped = _reshape_groups(params["self_blocks"], n_groups, every - 1)
    self_cache_g = jax.tree.map(
        lambda a: a.reshape((n_groups, every - 1) + a.shape[1:]), cache["attn"]
    )

    def group_body(carry, xs):
        x = carry
        sp, sc, cp, cc = xs

        def inner(c, lp_lc):
            lp, lc = lp_lc
            x2, nc = B.dense_block_decode(lp, c, lc, pos, ctx)
            return x2, nc

        x, new_self = jax.lax.scan(inner, x, (sp, sc), unroll=ctx.unroll)
        # gated cross attention against the cached image K/V
        from repro.kernels import ops as kops

        xn = rms_norm(x, cp["attn_norm"], cfg.norm_eps)
        bq = xn.shape[0]
        hd = cfg.resolved_head_dim
        q = apply_linear(xn, cp["attn"]["wq"], **ctx.kw).reshape(
            bq, 1, cfg.n_heads, hd)
        # image K/V are fully valid: length = T keeps the Pallas fast-path's
        # block-skip machinery uniform across self- and cross-attention
        t_img = cc["k"].shape[2]
        a = kops.decode_attention(q, cc["k"], cc["v"], cc["k_scale"],
                                  cc["v_scale"],
                                  length=jnp.full((bq,), t_img, jnp.int32),
                                  **ctx.kw)
        a = a.reshape(bq, 1, cfg.n_heads * hd)
        a = apply_linear(a, cp["attn"]["wo"], **ctx.kw)
        x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * a
        from repro.models.layers import glu_mlp

        hn = rms_norm(x, cp["mlp_norm"], cfg.norm_eps)
        m = glu_mlp(cp["mlp"], hn, cfg.act, shard=ctx.shard, **ctx.kw)
        x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * m
        return x, new_self

    h, new_self_g = jax.lax.scan(
        group_body, h,
        (self_grouped, self_cache_g, params["cross_blocks"], cache["cross"]),
        unroll=ctx.unroll,
    )
    new_cache["attn"] = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), new_self_g
    )
    new_cache["cross"] = cache["cross"]
    return h, new_cache


# ---------------------------------------------------------------------------
# serving: multi-step decode (the fused fast-path driver)
# ---------------------------------------------------------------------------


def _mask_padding_vocab(lf: Array, vocab_size: Optional[int]) -> Array:
    if vocab_size is not None and vocab_size < lf.shape[-1]:
        pad = jnp.arange(lf.shape[-1]) >= vocab_size
        lf = jnp.where(pad, -1e30, lf)
    return lf


def _top_p_mask(lf: Array, p: Array) -> Array:
    """Nucleus mask: keep the smallest set of tokens whose cumulative
    probability reaches ``p`` (the token that crosses the threshold is
    kept). Sorted-cumsum formulation: a sorted token survives iff the mass
    strictly before it is < p; the smallest surviving logit becomes the
    cutoff applied to the unsorted row. Composes with top-k by running on
    already-top-k-masked logits (masked entries carry ~zero probability)."""
    s_lf = jnp.sort(lf, axis=-1)[..., ::-1]
    sp = jax.nn.softmax(s_lf, axis=-1)
    csum = jnp.cumsum(sp, axis=-1)
    pb = jnp.reshape(jnp.asarray(p, jnp.float32),
                     jnp.shape(p) + (1,) * (lf.ndim - jnp.ndim(p)))
    keep = (csum - sp) < pb
    thresh = jnp.min(jnp.where(keep, s_lf, jnp.inf), axis=-1, keepdims=True)
    # p <= 0 or >= 1 disables the filter for that row
    thresh = jnp.where((pb > 0.0) & (pb < 1.0), thresh, -jnp.inf)
    return jnp.where(lf < thresh, -1e30, lf)


def sample_logits(logits: Array, key: Array, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0,
                  vocab_size: Optional[int] = None) -> Array:
    """Temperature / top-k / top-p sampling over the last axis. ``top_k <=
    0`` and ``top_p`` outside (0, 1) disable the respective filter;
    ``top_k == 1`` is argmax (greedy). Filters compose: top-k narrows the
    support first, then the nucleus mask runs on the filtered distribution.

    ``vocab_size`` masks the padding columns of a ``padded_vocab``-wide
    head: those logits come from untrained rows, and temperature sampling
    would otherwise give them real probability (greedy argmax rarely picks
    them, but sampled ids >= vocab_size have no detokenization)."""
    lf = _mask_padding_vocab(logits.astype(jnp.float32), vocab_size)
    lf = lf / jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
    if top_k and top_k > 0:
        kth = jax.lax.top_k(lf, min(top_k, lf.shape[-1]))[0][..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    if top_p and 0.0 < top_p < 1.0:
        lf = _top_p_mask(lf, jnp.asarray(top_p, jnp.float32))
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def sample_logits_ragged(logits: Array, keys: Array, *, temperature: Array,
                         top_k: Array, top_p: Array,
                         vocab_size: Optional[int] = None) -> Array:
    """Per-row sampling for the continuous-batching engine: every sampling
    parameter is a (B,) vector and every row draws from its own PRNG key, so
    a request's token stream is a function of (its seed, its step index)
    only — independent of which slot it occupies and who shares the batch.

    logits: (B, 1, V); keys: (B, 2) uint32 per-row keys. ``top_k[i] <= 0``
    / ``top_p[i]`` outside (0, 1) disable the filters for row i. top-k uses
    a sorted-rank threshold (``lax.top_k`` needs a static width; the kth
    value from a descending sort is the same threshold), then the nucleus
    mask runs on the masked sorted row — identical composition semantics to
    the scalar `sample_logits`."""
    lf = _mask_padding_vocab(logits.astype(jnp.float32), vocab_size)
    b, v = lf.shape[0], lf.shape[-1]
    t = jnp.maximum(temperature.astype(jnp.float32), 1e-6).reshape(b, 1, 1)
    lf = lf / t
    k = jnp.clip(top_k.astype(jnp.int32), 0, v).reshape(b, 1, 1)
    s_lf = jnp.sort(lf, axis=-1)[..., ::-1]
    kth = jnp.take_along_axis(s_lf, jnp.clip(k - 1, 0, v - 1), axis=-1)
    kth = jnp.where(k > 0, kth, -jnp.inf)
    lf = jnp.where(lf < kth, -1e30, lf)
    lf = _top_p_mask(lf, top_p.astype(jnp.float32))
    draw = jax.vmap(lambda kk, ll: jax.random.categorical(kk, ll, axis=-1))
    return draw(keys, lf).astype(jnp.int32)


def generate_tokens(params, cache: dict, first_tok: Array, n_steps: int,
                    cfg: ArchConfig, ctx: ModelContext, *,
                    key: Optional[Array] = None, temperature: float = 1.0,
                    top_k: int = 0, top_p: float = 0.0,
                    eos_id: Optional[int] = None):
    """Decode ``n_steps`` tokens as ONE ``lax.scan`` over decode_step.

    ``first_tok`` is the token sampled from the prefill logits (shape (B, 1),
    audio: (B, 1, n_cb)); the emitted sequence starts with it, matching the
    per-step Python loop this replaces. All tokens accumulate **on device**
    in the scan's stacked output — the caller does a single device→host
    transfer for the whole generation instead of one `int(tok[i, 0])` sync
    per token per sequence.

    ``key=None`` decodes greedily (argmax). With a PRNG key, the key rides
    the scan carry (split once per step, all still on device) and each step
    temperature/top-k/top-p samples via `sample_logits` — the sampling path
    costs zero extra host syncs. ``temperature``/``top_k``/``top_p`` only
    apply when a key is given.

    ``eos_id`` arms a per-row ``done`` mask in the scan carry: once a row
    emits the stop token it is frozen — every later step re-emits the same
    token and the sampled/argmax candidate is discarded, so the stacked
    output stays rectangular while finished rows do no further "real"
    decoding. The same freeze rule is what the continuous-batching engine's
    per-row ``active`` mask applies (there the host also reclaims the slot).

    Returns (toks, final_cache) with toks (n_steps, B, 1[, n_cb]) int32.
    """
    greedy = key is None
    if eos_id is not None and cfg.family == "audio":
        raise ValueError("eos_id is per-token-id; audio emits one token per "
                         "codebook per step — no single stop id applies")

    def body(carry, _):
        tok, c, k, done = carry
        logits, c = decode_step(params, c, tok, cfg, ctx)
        if greedy:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            k, sub = jax.random.split(k)
            nxt = sample_logits(logits, sub, temperature=temperature,
                                top_k=top_k, top_p=top_p,
                                vocab_size=cfg.vocab_size)
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
            nxt = jnp.where(done[:, None], tok, nxt)
        return (nxt, c, k, done), tok

    k0 = jax.random.PRNGKey(0) if greedy else key
    done0 = jnp.zeros((first_tok.shape[0],), bool)
    (_, cache, _, _), toks = jax.lax.scan(
        body, (first_tok.astype(jnp.int32), cache, k0, done0), None,
        length=n_steps, unroll=ctx.unroll,
    )
    return toks, cache


#: sentinel token id emitted by `ragged_decode_step` when a row's logits
#: are non-finite. Real token ids are always >= 0 (argmax / categorical
#: over the vocab; `Request` rejects negative prompt ids), so the engine's
#: host bookkeeping can detect a poisoned row from the *existing* per-step
#: device->host transfer — failure isolation costs zero extra transfers.
FAILED_TOKEN = -1


@_scoped("repro.ragged_decode_step")
def ragged_decode_step(params, cache: dict, tok: Array, pos: Array,
                       active: Array, sampling: dict, base_key: Array,
                       cfg: ArchConfig, ctx: ModelContext, *,
                       sample: bool = True,
                       block_tables: Optional[Array] = None,
                       poison: Optional[Array] = None):
    """One continuous-batching engine step: every slot decodes at its own
    position with its own sampling parameters; one compiled function serves
    any slot occupancy.

    tok: (B, 1) current token per slot; pos: (B,) per-row write position
    (= valid length); active: (B,) bool — inactive rows (free slots,
    retired or still-prefilling requests) freeze: their token and position
    are passed through unchanged and the sampled candidate is discarded
    (their KV write lands at the frozen ``pos`` and is overwritten on
    re-admission or the next real step — never attended, since per-row
    ``length`` masks it).

    ``sampling`` holds (B,) vectors: greedy (bool), temperature (f32),
    top_k (i32), top_p (f32), seed (i32), step (i32). Each row's PRNG key
    is ``fold_in(fold_in(base_key, seed), step)`` — a pure function of the
    request's seed and its sample index, so a request's stream is bitwise
    independent of slot assignment and batch composition.

    ``sample=False`` is the all-greedy static specialization: when the host
    knows no occupied slot samples, the sort/cumsum/PRNG machinery is
    compiled out entirely (greedy rows' tokens are identical either way —
    argmax ignores the sampler — so flipping the flag never changes a
    greedy row's stream).

    ``block_tables`` routes the attention cache through the paged
    BlockPool indirection (see `decode_step`); the engine keeps the tables
    host-side next to pos/active and uploads them only on block events.

    ``poison`` ((B,) bool, fault injection only) overwrites the chosen
    rows' logits with NaN *before* the finiteness guard, exercising the
    failure-isolation path end to end. None (the default) compiles the
    injection out entirely, so production engines pay nothing for it.

    Failure isolation: any active row whose logits are not entirely finite
    emits `FAILED_TOKEN` instead of a sampled id. When all logits are
    finite the guard's `where` is an identity, so healthy rows' token
    streams are bitwise unchanged by its presence.

    Returns (next_tok (B, 1), new_cache) — ``new_cache`` has no "pos" (the
    engine owns positions host-side and passes them in each step).
    """
    if cfg.family in ("vlm", "audio"):
        raise NotImplementedError(
            f"continuous batching not implemented for family {cfg.family!r}")
    c = dict(cache)
    c["pos"] = pos.astype(jnp.int32)
    logits, new_cache = decode_step(params, c, tok, cfg, ctx,
                                    block_tables=block_tables)
    if poison is not None:
        logits = jnp.where(poison[:, None, None], jnp.nan, logits)
    ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
    greedy_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    if sample:
        fold = lambda s, t: jax.random.fold_in(
            jax.random.fold_in(base_key, s), t)
        keys = jax.vmap(fold)(sampling["seed"], sampling["step"])
        sampled = sample_logits_ragged(
            logits, keys, temperature=sampling["temperature"],
            top_k=sampling["top_k"], top_p=sampling["top_p"],
            vocab_size=cfg.vocab_size)
        nxt = jnp.where(sampling["greedy"][:, None], greedy_tok, sampled)
    else:
        nxt = greedy_tok
    nxt = jnp.where(active[:, None], nxt, tok)
    nxt = jnp.where((active & ~ok)[:, None], jnp.int32(FAILED_TOKEN), nxt)
    new_cache["pos"] = jnp.where(active, pos + 1, pos)
    return nxt, new_cache


@_scoped("repro.prefill_chunk")
def prefill_chunk(params, attn_cache: dict, tokens: Array, start: Array,
                  cfg: ArchConfig, ctx: ModelContext, *,
                  last_pos: Optional[Array] = None,
                  block_tables: Optional[Array] = None,
                  prefix_bucket: Optional[int] = None):
    """Advance one slot's prefill by a chunk of C prompt tokens.

    attn_cache: a single-row attention cache (leaves (L, 1, KVH, S, D) /
    (L, 1, KVH, S)) — or, with ``block_tables`` ((1, max_blocks) int32),
    the paged BlockPool arrays (leaves (L, N_phys, KVH, page, D) /
    (L, N_phys, KVH, page)) shared by every slot, with the chunk's writes
    and reads resolved through the table (see `attention.attend_chunk`;
    the engine pre-maps every block covering ``start + C``). tokens:
    (1, C) at absolute positions ``start .. start+C-1``. Each layer
    writes the chunk's quantized KV and attends it against the int8
    prefix — the prefix-clamped Pallas kernel on TPU, the
    ``prefix_bucket``-sliced XLA fallback elsewhere (the bucket is a
    static bound >= start+C, so the per-chunk cost is O(prefix bucket),
    not O(max_len)). With ``last_pos`` (chunk-local index of the prompt's
    final token) the first-token logits are returned; mid-prompt chunks
    pass None and get logits=None. dense/moe families only — SSM state
    carries can't resume from a written cache row.
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"chunked prefill not implemented for family {cfg.family!r}")
    h = embed_tokens(params, tokens, cfg, ctx)

    def body(carry, xs):
        x = carry
        lp, lc = xs
        x, nc = B.dense_block_chunk(lp, x, lc, start, ctx,
                                    block_tables=block_tables,
                                    prefix_bucket=prefix_bucket)
        return x, nc

    h, updated = jax.lax.scan(body, h, (params["blocks"], attn_cache),
                              unroll=ctx.unroll)
    if last_pos is None:
        return None, updated
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    h_last = jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1)
    logits = logits_last_token(h_last, lm_head_weight(params, cfg), ctx.shard)
    return logits, updated


# ---------------------------------------------------------------------------
# cache init (decode-only dry-run cells build the cache from specs)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    cache: dict[str, Any] = {"pos": jnp.asarray(0, jnp.int32)}
    if cfg.family in ("dense", "moe", "audio"):
        cache["attn"] = attn_mod.init_kv_cache(cfg, batch, max_len)
    elif cfg.family == "ssm":
        cache["ssm"] = ssm_mod.init_ssm_cache(cfg, batch)
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.shared_attn_every
        cache["ssm"] = ssm_mod.init_ssm_cache(cfg, batch)
        cache["attn"] = attn_mod.init_kv_cache(cfg, batch, max_len,
                                               n_layers=n_groups)
    elif cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = n_groups * (cfg.cross_attn_every - 1)
        cache["attn"] = attn_mod.init_kv_cache(cfg, batch, max_len,
                                               n_layers=n_self)
        hd = cfg.resolved_head_dim
        t = cfg.n_image_tokens
        cache["cross"] = {
            "k": jnp.zeros((n_groups, batch, cfg.n_kv_heads, t, hd), jnp.int8),
            "k_scale": jnp.zeros((n_groups, batch, cfg.n_kv_heads, t),
                                 jnp.float32),
            "v": jnp.zeros((n_groups, batch, cfg.n_kv_heads, t, hd), jnp.int8),
            "v_scale": jnp.zeros((n_groups, batch, cfg.n_kv_heads, t),
                                 jnp.float32),
        }
    return cache

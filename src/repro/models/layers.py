"""Shared model layers: norms, RoPE, activations, linear dispatch.

All layers are pure functions over param pytrees. A "linear weight" is either
a plain (K, N) array (training / fp serving) or a `QuantLinear` container
(ABQ serving path) — `apply_linear` dispatches, so every block definition is
written once and runs in both modes. This is how the paper's engine slots in
as a first-class feature: swap the leaves, keep the model.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import PackedWeight
from repro.kernels import ops as kops

Array = jax.Array


# ---------------------------------------------------------------------------
# quantized-linear container (ABQ serve path)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QuantLinear:
    """A calibrated, packed ABQ linear.

    pw: bit-plane packed weight (already includes balance scaling s and the
        rank-1 compensation folded in).
    act_inv_s: optional (K,) reciprocal balance vector applied to the
        activation at runtime (X / s of Eq. 1); None when folded upstream.
    act_bits: activation bit-width p (int8 container).
    """

    pw: PackedWeight
    act_inv_s: Optional[Array]
    act_bits: int

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("pw"), self.pw), (ga("act_inv_s"), self.act_inv_s)), \
            (self.act_bits,)

    def tree_flatten(self):
        return (self.pw, self.act_inv_s), (self.act_bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        pw, inv_s = children
        return cls(pw, inv_s, aux[0])


def index_linear(w: Any, i: int) -> Any:
    """Index a stacked linear (array or QuantLinear) on its leading axis."""
    if isinstance(w, QuantLinear):
        return jax.tree.map(lambda a: a[i], w)
    return w[i]


def apply_linear(x: Array, w: Any, *, backend: str = "auto",
                 interpret: bool = False) -> Array:
    """x [..., K] @ w -> [..., N]; dispatches dense vs ABQ-quantized."""
    if isinstance(w, QuantLinear):
        if w.act_inv_s is not None:
            x = x * w.act_inv_s
        return kops.abq_linear(
            x, w.pw, act_bits=w.act_bits, out_dtype=x.dtype,
            backend=backend, interpret=interpret,
        )
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(x.dtype)


def activation(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def glu_mlp(params: dict, x: Array, act: str, *, backend: str = "auto",
            interpret: bool = False, shard=None) -> Array:
    """Gated MLP (SwiGLU/GeGLU) or plain MLP (relu2: gate acts as fc1).

    ``shard(x, *logical)`` pins the ff intermediates to the tensor axis so
    GSPMD never replicates the (tokens × d_ff) tensors (the 1-block memory
    bisect in EXPERIMENTS.md §Perf shows why this matters)."""
    sh = shard or (lambda t, *l: t)
    gate = sh(apply_linear(x, params["w_gate"], backend=backend,
                           interpret=interpret), "batch", None, "tensor")
    if "w_up" in params:
        up = sh(apply_linear(x, params["w_up"], backend=backend,
                             interpret=interpret), "batch", None, "tensor")
        h = activation(gate, act) * up
    else:
        h = activation(gate, act)
    return apply_linear(h, params["w_down"], backend=backend, interpret=interpret)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (S,) or (B, S) absolute positions."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.bfloat16, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)

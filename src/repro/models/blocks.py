"""Transformer/Mamba/MoE/cross-attention blocks + the layer-stack drivers.

Blocks are pure functions over a per-layer param dict. The LM (lm.py) stacks
layer params on a leading axis and drives them with lax.scan (compile-time
O(1) in depth — required for the 64–100 layer production configs), wrapping
the body in jax.checkpoint for training remat.

Every block exposes the two hooks the ABQ calibration needs (§3.2):
  * the block output (for the DLC loss) — just the return value;
  * the attention probabilities (for the AKL loss) — via ``return_attn``,
    which switches attention to the reference (non-flash) path since the
    whole point is to look at the map. Calibration runs on short sequences,
    so the quadratic map is fine there.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ArchConfig
from repro.dist.sharding import ShardingRules, constraint
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_linear,
    apply_rope,
    dense_init,
    glu_mlp,
    rms_norm,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelContext:
    """Static execution context threaded through the model."""

    cfg: ArchConfig
    mesh: Optional[Mesh] = None
    rules: ShardingRules = dataclasses.field(default_factory=ShardingRules)
    backend: str = "auto"  # kernel dispatch: auto | xla | pallas
    remat: bool = True
    interpret: bool = False
    # roofline-probe knobs: unroll every scan so cost_analysis counts true
    # totals (used by dryrun --probe; see benchmarks/roofline.py)
    unroll: bool = False
    flash_block: int = 1024

    @property
    def kw(self):
        return dict(backend=self.backend, interpret=self.interpret)

    @property
    def loop_kw(self):
        return dict(unroll=self.unroll, flash_block=self.flash_block)

    def shard(self, x: Array, *logical) -> Array:
        return constraint(x, self.mesh, self.rules, *logical)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_mlp_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {"w_gate": dense_init(ks[0], (d, ff), dtype),
         "w_down": dense_init(ks[2], (ff, d), dtype)}
    if cfg.act in ("silu", "gelu"):  # gated (SwiGLU / GeGLU)
        p["w_up"] = dense_init(ks[1], (d, ff), dtype)
    return p


def init_dense_block(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_mod.init_attn_params(ks[0], cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp_params(ks[1], cfg, dtype),
    }


def init_moe_block(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_mod.init_attn_params(ks[0], cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "moe": moe_mod.init_moe_params(ks[1], cfg, dtype),
    }


def init_ssm_block(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm_mod.init_ssm_params(key, cfg, dtype),
    }


def init_cross_block(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    """Gated cross-attention layer (llama-3.2-vision style)."""
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_mod.init_attn_params(ks[0], cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp_params(ks[1], cfg, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


# ---------------------------------------------------------------------------
# forward: full-sequence (train / prefill)
# ---------------------------------------------------------------------------


def _attention_with_probs(params, x_ln, cfg: ArchConfig, ctx: ModelContext):
    """Reference-path attention that also returns the probability map
    (calibration-only; short sequences)."""
    b, s, _ = x_ln.shape
    hd = cfg.resolved_head_dim
    q, k, v = attn_mod._project_qkv(
        params, x_ln, cfg, jnp.arange(s), rope=True, **ctx.kw
    )
    rep = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) / (hd**0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    out = out.astype(x_ln.dtype).reshape(b, s, cfg.n_heads * hd)
    out = apply_linear(out, params["wo"], **ctx.kw)
    return out, probs


def dense_block(
    params: dict,
    x: Array,
    ctx: ModelContext,
    *,
    return_attn: bool = False,
):
    """Pre-norm attention + (Swi/Ge)GLU MLP block. Returns (y, attn_probs?)."""
    cfg = ctx.cfg
    x = ctx.shard(x, "batch", "seq", None)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    probs = None
    if return_attn:
        a, probs = _attention_with_probs(params["attn"], h, cfg, ctx)
    else:
        a = attn_mod.attend_train(params["attn"], h, cfg, shard=ctx.shard,
                                   **ctx.loop_kw, **ctx.kw)
    x = x + ctx.shard(a, "batch", "seq", None)
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    m = glu_mlp(params["mlp"], h, cfg.act, shard=ctx.shard, **ctx.kw)
    x = x + ctx.shard(m, "batch", "seq", None)
    return x, probs


def moe_block(params: dict, x: Array, ctx: ModelContext, *,
              return_attn: bool = False):
    cfg = ctx.cfg
    x = ctx.shard(x, "batch", "seq", None)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    probs = None
    if return_attn:
        a, probs = _attention_with_probs(params["attn"], h, cfg, ctx)
    else:
        a = attn_mod.attend_train(params["attn"], h, cfg, shard=ctx.shard,
                                   **ctx.loop_kw, **ctx.kw)
    x = x + ctx.shard(a, "batch", "seq", None)
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    m, aux = moe_mod.moe_ffn(
        params["moe"], h, cfg,
        mesh=ctx.mesh,
        dp_axes=ctx.rules.batch if ctx.rules.batch else (),
        tp_axis=ctx.rules.tensor if isinstance(ctx.rules.tensor, str) else "model",
        **ctx.kw,
    )
    x = x + ctx.shard(m, "batch", "seq", None)
    return x, probs, aux


def ssm_block(params: dict, x: Array, ctx: ModelContext):
    cfg = ctx.cfg
    x = ctx.shard(x, "batch", "seq", None)
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    y = ssm_mod.ssm_forward(params["ssm"], h, cfg, shard=ctx.shard,
                            unroll=ctx.unroll, **ctx.kw)
    return x + ctx.shard(y, "batch", "seq", None)


def cross_block(params: dict, x: Array, context: Array, ctx: ModelContext):
    """Gated cross-attention + MLP (vision-text injection)."""
    cfg = ctx.cfg
    x = ctx.shard(x, "batch", "seq", None)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    a = attn_mod.cross_attend(params["attn"], h, context, cfg,
                              **ctx.loop_kw, **ctx.kw)
    x = x + jnp.tanh(params["gate_attn"]).astype(x.dtype) * ctx.shard(
        a, "batch", "seq", None
    )
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    m = glu_mlp(params["mlp"], h, cfg.act, shard=ctx.shard, **ctx.kw)
    x = x + jnp.tanh(params["gate_mlp"]).astype(x.dtype) * ctx.shard(
        m, "batch", "seq", None
    )
    return x


# ---------------------------------------------------------------------------
# forward: prefill (returns quantized KV) and decode (consumes cache)
# ---------------------------------------------------------------------------


def dense_block_prefill(params: dict, x: Array, ctx: ModelContext):
    cfg = ctx.cfg
    x = ctx.shard(x, "batch", "seq", None)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    a, kv = attn_mod.attend_prefill(params["attn"], h, cfg, shard=ctx.shard,
                                    **ctx.loop_kw, **ctx.kw)
    x = x + ctx.shard(a, "batch", "seq", None)
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    if "moe" in params:
        m, _ = moe_mod.moe_ffn(
            params["moe"], h, cfg,
            mesh=ctx.mesh,
            dp_axes=ctx.rules.batch if ctx.rules.batch else (),
            tp_axis=ctx.rules.tensor if isinstance(ctx.rules.tensor, str) else "model",
            **ctx.kw,
        )
    else:
        m = glu_mlp(params["mlp"], h, cfg.act, shard=ctx.shard, **ctx.kw)
    x = x + ctx.shard(m, "batch", "seq", None)
    return x, kv


def dense_block_decode(params: dict, x: Array, layer_cache: dict, pos: Array,
                       ctx: ModelContext, *, block_tables=None):
    cfg = ctx.cfg
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    a, new_cache = attn_mod.attend_decode(
        params["attn"], h, layer_cache, pos, cfg,
        block_tables=block_tables, shard=ctx.shard, **ctx.kw
    )
    x = x + a
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    if "moe" in params:
        m, _ = moe_mod.moe_ffn(
            params["moe"], h, cfg,
            mesh=ctx.mesh,
            dp_axes=ctx.rules.batch if ctx.rules.batch else (),
            tp_axis=ctx.rules.tensor if isinstance(ctx.rules.tensor, str) else "model",
            **ctx.kw,
        )
    else:
        m = glu_mlp(params["mlp"], h, cfg.act, shard=ctx.shard, **ctx.kw)
    x = x + m
    return x, new_cache


def dense_block_chunk(params: dict, x: Array, layer_cache: dict, start: Array,
                      ctx: ModelContext, *, block_tables=None,
                      prefix_bucket=None):
    """Chunked-prefill block step: C tokens against the quantized cache
    (see `attention.attend_chunk`). Same residual structure as
    `dense_block_decode`, multi-token. ``block_tables`` routes the cache
    through the paged BlockPool indirection; ``prefix_bucket`` is the
    static prefix bound the XLA fallback slices to."""
    cfg = ctx.cfg
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    a, new_cache = attn_mod.attend_chunk(
        params["attn"], h, layer_cache, start, cfg,
        block_tables=block_tables, prefix_bucket=prefix_bucket,
        shard=ctx.shard, **ctx.kw
    )
    x = x + a
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    if "moe" in params:
        m, _ = moe_mod.moe_ffn(
            params["moe"], h, cfg,
            mesh=ctx.mesh,
            dp_axes=ctx.rules.batch if ctx.rules.batch else (),
            tp_axis=ctx.rules.tensor if isinstance(ctx.rules.tensor, str) else "model",
            **ctx.kw,
        )
    else:
        m = glu_mlp(params["mlp"], h, cfg.act, shard=ctx.shard, **ctx.kw)
    x = x + m
    return x, new_cache


def ssm_block_decode(params: dict, x: Array, layer_cache: dict,
                     ctx: ModelContext):
    cfg = ctx.cfg
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    y, new_cache = ssm_mod.ssm_decode(params["ssm"], h, layer_cache, cfg, **ctx.kw)
    return x + y, new_cache

"""Model zoo: layers, attention, SSM, MoE, blocks, LM drivers."""

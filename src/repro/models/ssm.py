"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like math
inside chunks of length Q, a linear recurrence across chunks (lax.scan), so
compute is O(S·Q) and the materialized score block is (Q × Q) — this is the
sub-quadratic path that makes the long_500k shape feasible.

Decode is the O(1) recurrent form over the (H, P, N) state.

Layout follows the Mamba2 reference: d_inner = expand·d, heads H = d_inner/P
(P = headdim), n_groups = 1, state N = cfg.ssm_state. The input projection is
split into separate weight matrices (z, x, B, C, dt) instead of one fused
matrix so tensor-parallel sharding stays clean (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import apply_linear, dense_init, rms_norm

Array = jax.Array


def init_ssm_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    nh = cfg.ssm_heads
    ns = cfg.ssm_state
    ks = jax.random.split(key, 10)
    # dt init: log-uniform in [1e-3, 1e-1], stored through inverse softplus
    dt0 = jnp.exp(
        jax.random.uniform(ks[5], (nh,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    return {
        "wz": dense_init(ks[0], (d, din), dtype),
        "wx": dense_init(ks[1], (d, din), dtype),
        "wB": dense_init(ks[2], (d, ns), dtype),
        "wC": dense_init(ks[3], (d, ns), dtype),
        "wdt": dense_init(ks[4], (d, nh), dtype),
        "dt_bias": jnp.log(jnp.expm1(dt0)),
        "A_log": jnp.log(
            jax.random.uniform(ks[6], (nh,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        # separate depthwise convs per stream (x / B / C) so the x-conv
        # shards cleanly over the tensor axis while B/C stay replicated
        "conv_x": (jax.random.normal(ks[7], (cfg.ssm_conv, din), jnp.float32)
                   * cfg.ssm_conv**-0.5).astype(dtype),
        "conv_B": (jax.random.normal(ks[9], (cfg.ssm_conv, ns), jnp.float32)
                   * cfg.ssm_conv**-0.5).astype(dtype),
        "conv_C": (jax.random.normal(ks[9], (cfg.ssm_conv, ns), jnp.float32)
                   * cfg.ssm_conv**-0.5).astype(dtype),
        "norm": jnp.ones((din,), dtype),
        "wout": dense_init(ks[8], (din, d), dtype),
    }


def _causal_conv(u: Array, w: Array, state: Optional[Array] = None):
    """Depthwise causal conv, width W, as W shifted adds.

    u: (B, S, C); w: (W, C). Returns (y, new_state) where state holds the
    last W-1 inputs for decode continuation.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros_like(u, dtype=jnp.float32)
    s = u.shape[1]
    for i in range(width):
        y = y + ext[:, i : i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = ext[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(y).astype(u.dtype), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, return_state: bool = False,
                 unroll: bool = False):
    """Chunked SSD: one lax.scan over chunks carrying the (H, N, P) state.

    Per chunk (length q): the intra-chunk quadratic part materializes only a
    (B, q, q, H) decay-weighted score block (the SSD analogue of a flash
    attention tile), the inter-chunk part applies the carried state, and the
    chunk's contribution updates the state for the next step. Memory is
    O(B·q²·H) regardless of S — the sub-quadratic property the long_500k
    shape depends on.

    xh: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm/Cm: (B, S, N).
    Returns y: (B, S, H, P) in fp32.
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s) if s >= 1 else chunk
    pad = (-s) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q

    # chunk-major scan inputs: (nc, B, q, ...)
    xh_c = xh.astype(jnp.float32).reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    dt_c = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    Bm_c = Bm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    Cm_c = Cm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def scan_fn(h_prev, inp):
        xc, dtc, bc, cc = inp  # (B,q,H,P), (B,q,H), (B,q,N), (B,q,N)
        la = dtc * A[None, None, :]  # (B,q,H), <= 0
        cs = jnp.cumsum(la, axis=1)
        # intra-chunk: L[s,t] = exp(cs_s - cs_t) · 1[s>=t]
        diff = cs[:, :, None, :] - cs[:, None, :, :]  # (B,q,q,H)
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bqn,btn->bqt", cc, bc)
        dx = dtc[..., None] * xc  # (B,q,H,P)
        y_intra = jnp.einsum("bqt,bqth,bthp->bqhp", cb, lmat, dx)
        # inter-chunk: apply carried state
        dec_from_start = jnp.exp(cs)  # (B,q,H)
        y_inter = jnp.einsum("bqn,bqh,bhnp->bqhp", cc, dec_from_start, h_prev)
        # state update: h <- exp(sum la) h + sum_t exp(cs_end - cs_t) dt_t B_t⊗x_t
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)  # (B,q,H)
        st = jnp.einsum("bqn,bqh,bqhp->bhnp", bc, decay_to_end * dtc, xc)
        h_new = h_prev * jnp.exp(cs[:, -1, :])[:, :, None, None] + st
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    h_final, y_c = jax.lax.scan(scan_fn, h0, (xh_c, dt_c, Bm_c, Cm_c),
                                unroll=unroll)
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, p)
    if return_state:
        # padded steps carry dt=0 -> decay 1, zero contribution, so h_final
        # is exactly the state after the last real token.
        return y[:, :s], h_final
    return y[:, :s]


def ssm_forward(
    params: dict,
    x: Array,
    cfg: ArchConfig,
    *,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
    unroll: bool = False,
) -> Array:
    """Full-sequence Mamba2 block core (pre-norm residual handled by caller)."""
    b, s, _ = x.shape
    sh = shard or (lambda t, *l: t)
    nh, p, ns = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = sh(apply_linear(x, params["wz"], backend=backend, interpret=interpret),
           "batch", None, "tensor")
    xs = sh(apply_linear(x, params["wx"], backend=backend, interpret=interpret),
            "batch", None, "tensor")
    Bm = sh(apply_linear(x, params["wB"], backend=backend, interpret=interpret),
            "batch", None, None)
    Cm = sh(apply_linear(x, params["wC"], backend=backend, interpret=interpret),
            "batch", None, None)
    dt_raw = sh(apply_linear(x, params["wdt"], backend=backend,
                             interpret=interpret), "batch", None, "tensor")

    xs, _ = _causal_conv(xs, params["conv_x"])
    Bm, _ = _causal_conv(Bm, params["conv_B"])
    Cm, _ = _causal_conv(Cm, params["conv_C"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = sh(xs.reshape(b, s, nh, p), "batch", None, "tensor", None)
    y = _ssd_chunked(xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                     cfg.ssm_chunk, unroll=unroll)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = sh(y.reshape(b, s, cfg.d_inner).astype(x.dtype), "batch", None, "tensor")
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return apply_linear(y, params["wout"], backend=backend, interpret=interpret)


def ssm_forward_with_state(
    params: dict,
    x: Array,
    cfg: ArchConfig,
    *,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
    unroll: bool = False,
):
    """Full-sequence forward that also returns the decode cache for this
    layer: conv tails (last W−1 raw conv inputs) + final SSD state. Used by
    prefill so decode can continue exactly where the prompt ended."""
    b, s, _ = x.shape
    sh = shard or (lambda t, *l: t)
    nh, p, ns = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = sh(apply_linear(x, params["wz"], backend=backend, interpret=interpret),
           "batch", None, "tensor")
    xs_raw = sh(apply_linear(x, params["wx"], backend=backend,
                             interpret=interpret), "batch", None, "tensor")
    Bm_raw = apply_linear(x, params["wB"], backend=backend, interpret=interpret)
    Cm_raw = apply_linear(x, params["wC"], backend=backend, interpret=interpret)
    dt_raw = sh(apply_linear(x, params["wdt"], backend=backend,
                             interpret=interpret), "batch", None, "tensor")

    xs, cx = _causal_conv(xs_raw, params["conv_x"])
    Bm, cB = _causal_conv(Bm_raw, params["conv_B"])
    Cm, cC = _causal_conv(Cm_raw, params["conv_C"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = sh(xs.reshape(b, s, nh, p), "batch", None, "tensor", None)
    y, h_final = _ssd_chunked(
        xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
        cfg.ssm_chunk, return_state=True, unroll=unroll,
    )
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = apply_linear(y, params["wout"], backend=backend, interpret=interpret)
    state = {
        "conv_x": cx.astype(x.dtype),
        "conv_B": cB.astype(x.dtype),
        "conv_C": cC.astype(x.dtype),
        "state": h_final,
    }
    return out, state


def init_ssm_cache(cfg: ArchConfig, batch: int, n_layers: Optional[int] = None,
                   dtype=jnp.bfloat16) -> dict:
    ell = cfg.n_layers if n_layers is None else n_layers
    w1 = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((ell, batch, w1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((ell, batch, w1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((ell, batch, w1, cfg.ssm_state), dtype),
        "state": jnp.zeros(
            (ell, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
            jnp.float32,
        ),
    }


def ssm_decode(
    params: dict,
    x: Array,
    layer_cache: dict,
    cfg: ArchConfig,
    *,
    backend: str = "auto",
    interpret: bool = False,
):
    """One-token recurrent step. x: (B, 1, D); cache: conv_[xBC] (B,W-1,·),
    state (B,H,N,P). Returns (out (B,1,D), new layer_cache)."""
    b = x.shape[0]
    nh, p, ns = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = apply_linear(x, params["wz"], backend=backend, interpret=interpret)
    xs = apply_linear(x, params["wx"], backend=backend, interpret=interpret)
    Bm = apply_linear(x, params["wB"], backend=backend, interpret=interpret)
    Cm = apply_linear(x, params["wC"], backend=backend, interpret=interpret)
    dt_raw = apply_linear(x, params["wdt"], backend=backend, interpret=interpret)

    xs, ncx = _causal_conv(xs, params["conv_x"], state=layer_cache["conv_x"])
    Bm, ncB = _causal_conv(Bm, params["conv_B"], state=layer_cache["conv_B"])
    Cm, ncC = _causal_conv(Cm, params["conv_C"], state=layer_cache["conv_C"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B, H)
    xh = xs.reshape(b, nh, p).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # (B, N)
    Cv = Cm[:, 0].astype(jnp.float32)
    # h <- decay h + dt * B ⊗ x
    h_new = (
        layer_cache["state"] * decay[:, :, None, None]
        + dt[:, :, None, None] * Bv[:, None, :, None] * xh[:, :, None, :]
    )
    y = jnp.einsum("bn,bhnp->bhp", Cv, h_new)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = apply_linear(y, params["wout"], backend=backend, interpret=interpret)
    return out, {
        "conv_x": ncx.astype(layer_cache["conv_x"].dtype),
        "conv_B": ncB.astype(layer_cache["conv_B"].dtype),
        "conv_C": ncC.astype(layer_cache["conv_C"].dtype),
        "state": h_new,
    }

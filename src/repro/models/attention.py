"""GQA self-attention and cross-attention with an int8-quantized KV cache.

Three entry points used by the blocks:
  attend_train   — flash attention over the whole sequence (train/prefill)
  attend_decode  — one token against the quantized cache
  cross_attend   — attention over (stubbed) image/context embeddings

The KV cache is the paper's regime: per-token-per-head symmetric int8
(§4.1 "for activation and KV Cache we perform per-token quantization"),
so decode reads ~half the bytes of a bf16 cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.kernels import ops as kops
from repro.models.layers import apply_linear, apply_rope, rms_norm

Array = jax.Array


def init_attn_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    from repro.models.layers import dense_init

    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, x, cfg: ArchConfig, positions, *, backend, interpret,
                 rope: bool = True, shard=None):
    b, s, _ = x.shape
    sh = shard or (lambda t, *l: t)
    hd = cfg.resolved_head_dim
    q = apply_linear(x, params["wq"], backend=backend, interpret=interpret)
    k = apply_linear(x, params["wk"], backend=backend, interpret=interpret)
    v = apply_linear(x, params["wv"], backend=backend, interpret=interpret)
    # heads ride the tensor axis from here to the output projection
    q = sh(q.reshape(b, s, cfg.n_heads, hd), "batch", None, "tensor", None)
    k = sh(k.reshape(b, s, cfg.n_kv_heads, hd), "batch", None, "tensor", None)
    v = sh(v.reshape(b, s, cfg.n_kv_heads, hd), "batch", None, "tensor", None)
    if cfg.qk_norm:
        q = sh(rms_norm(q, params["q_norm"], cfg.norm_eps),
               "batch", None, "tensor", None)
        k = sh(rms_norm(k, params["k_norm"], cfg.norm_eps),
               "batch", None, "tensor", None)
    if rope:
        q = sh(apply_rope(q, positions, cfg.rope_theta),
               "batch", None, "tensor", None)
        k = sh(apply_rope(k, positions, cfg.rope_theta),
               "batch", None, "tensor", None)
    return q, k, v


def attend_train(
    params: dict,
    x: Array,
    cfg: ArchConfig,
    *,
    positions: Optional[Array] = None,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
    unroll: bool = False,
    flash_block: int = 1024,
) -> Array:
    """Full-sequence causal attention; returns (B, S, D)."""
    b, s, _ = x.shape
    sh = shard or (lambda t, *l: t)
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(
        params, x, cfg, positions, backend=backend, interpret=interpret,
        shard=shard,
    )
    out = kops.flash_attention(q, k, v, causal=True, backend=backend,
                               interpret=interpret, unroll=unroll,
                               block_q=flash_block, block_k=flash_block)
    out = sh(out, "batch", None, "tensor", None)
    out = out.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    return apply_linear(out, params["wo"], backend=backend, interpret=interpret)


def quantize_kv(k: Array, v: Array) -> tuple[Array, Array, Array, Array]:
    """(B,S,KVH,D) -> int8 values + f32 per-token-per-head scales."""
    def one(t):
        amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale

    kq, ks = one(k)
    vq, vs = one(v)
    return kq, ks, vq, vs


def quantize_kv_cached(k: Array, v: Array):
    """(B,S,KVH,D) -> cache-layout int8 KV: values (B,KVH,S,D), scales
    (B,KVH,S). §Perf iteration 3: the cache is stored in the layout the
    decode contraction consumes, so no per-step transpose of the (huge)
    cache — the one transpose happens here, at prefill, amortized over the
    whole decode."""
    kq, ks, vq, vs = quantize_kv(k, v)
    return (kq.transpose(0, 2, 1, 3), ks[..., 0].transpose(0, 2, 1),
            vq.transpose(0, 2, 1, 3), vs[..., 0].transpose(0, 2, 1))


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> dict:
    """Stacked attention-native layout: values (L, B, KVH, S, D) int8,
    scales (L, B, KVH, S) fp32."""
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    ell = cfg.n_layers if n_layers is None else n_layers
    return {
        "k": jnp.zeros((ell, batch, kvh, max_len, hd), jnp.int8),
        "k_scale": jnp.zeros((ell, batch, kvh, max_len), jnp.float32),
        "v": jnp.zeros((ell, batch, kvh, max_len, hd), jnp.int8),
        "v_scale": jnp.zeros((ell, batch, kvh, max_len), jnp.float32),
    }


def attend_prefill(
    params: dict,
    x: Array,
    cfg: ArchConfig,
    *,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
    unroll: bool = False,
    flash_block: int = 1024,
):
    """Like attend_train but also returns the quantized (k, ks, v, vs)."""
    b, s, _ = x.shape
    sh = shard or (lambda t, *l: t)
    positions = jnp.arange(s)
    q, k, v = _project_qkv(
        params, x, cfg, positions, backend=backend, interpret=interpret,
        shard=shard,
    )
    out = kops.flash_attention(q, k, v, causal=True, backend=backend,
                               interpret=interpret, unroll=unroll,
                               block_q=flash_block, block_k=flash_block)
    out = sh(out, "batch", None, "tensor", None)
    out = out.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    out = apply_linear(out, params["wo"], backend=backend, interpret=interpret)
    kq, ks, vq, vs = quantize_kv_cached(k, v)
    return out, (kq, ks, vq, vs)


def attend_decode(
    params: dict,
    x: Array,
    layer_cache: dict,
    pos: Array,
    cfg: ArchConfig,
    *,
    block_tables: Optional[Array] = None,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
):
    """One-token step. x: (B, 1, D); layer_cache holds (B, KVH, S, D) int8
    values + (B, KVH, S) scales (attention-native layout).

    ``pos`` is either a scalar (the legacy lockstep batcher: every row is at
    the same position) or a (B,) vector (the continuous-batching engine:
    each cache row advances independently — per-row RoPE positions, per-row
    KV write indices, per-row valid lengths for the kernel's block skip).

    ``block_tables`` ((B, max_blocks) int32) switches to the **paged**
    cache: layer_cache leaves are BlockPool arrays ((N_phys, KVH, page, D)
    values / (N_phys, KVH, page) scales shared by every row) and the KV
    write resolves ``pos`` through the table — logical block
    ``pos // page`` → physical pool block, offset ``pos % page`` — as a
    per-row scatter. The engine guarantees the target block is mapped
    before the step runs (alloc-on-demand); inactive rows' tables point at
    the TRASH block, which absorbs their frozen garbage write.

    Returns (out, updated layer_cache). The new token's k/v are quantized and
    written at ``pos`` (dynamic index); attention masks positions > pos.
    """
    b = x.shape[0]
    ragged = jnp.ndim(pos) == 1
    if ragged:
        positions = pos.astype(jnp.int32)[:, None]
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(
        params, x, cfg, positions, backend=backend, interpret=interpret,
        shard=shard,
    )
    kq, ks, vq, vs = quantize_kv_cached(k, v)  # (B,KVH,1,D) / (B,KVH,1)

    if block_tables is not None:
        # paged write: scatter each row's new token into its mapped pool
        # block (advanced-index scatter over (phys, kvh, offset))
        page = layer_cache["k"].shape[2]
        pos_v = pos.astype(jnp.int32) if ragged \
            else jnp.full((b,), pos, jnp.int32)
        phys = jnp.take_along_axis(
            block_tables.astype(jnp.int32), (pos_v // page)[:, None],
            axis=1)
        i0 = phys  # (B, 1)
        i1 = jnp.arange(layer_cache["k"].shape[1])[None, :]  # (1, KVH)
        i2 = (pos_v % page)[:, None]  # (B, 1)

        def write(cache, val, axis):
            del axis
            return cache.at[i0, i1, i2].set(
                val[:, :, 0].astype(cache.dtype))
    elif ragged:
        def write(cache, val, axis):
            # per-row scatter: each batch row updates its own position
            return jax.vmap(
                lambda c, v_, p: jax.lax.dynamic_update_slice_in_dim(
                    c, v_, p, axis=axis - 1)
            )(cache, val, pos)
    else:
        def write(cache, val, axis):
            return jax.lax.dynamic_update_slice_in_dim(cache, val, pos,
                                                       axis=axis)

    new_cache = {
        "k": write(layer_cache["k"], kq, 2),
        "k_scale": write(layer_cache["k_scale"],
                         ks.astype(layer_cache["k_scale"].dtype), 2),
        "v": write(layer_cache["v"], vq, 2),
        "v_scale": write(layer_cache["v_scale"],
                         vs.astype(layer_cache["v_scale"].dtype), 2),
    }
    # length = pos + 1 is what makes the Pallas fast-path's S-block skip
    # reachable from the serving scan: early decode steps only stream the
    # blocks covering the valid prefix, not the whole max_len cache. With a
    # (B,) pos this is per-row — ragged batches are free in the kernel
    # (scalar-prefetched lengths drive the block skip row by row).
    if ragged:
        length = (pos + 1).astype(jnp.int32)
    else:
        length = jnp.full((b,), pos + 1, jnp.int32)
    out = kops.decode_attention(
        q,
        new_cache["k"],
        new_cache["v"],
        new_cache["k_scale"],
        new_cache["v_scale"],
        length=length,
        block_tables=block_tables,
        backend=backend,
        interpret=interpret,
    )
    out = out.reshape(b, 1, cfg.n_heads * cfg.resolved_head_dim)
    out = apply_linear(out, params["wo"], backend=backend, interpret=interpret)
    return out, new_cache


def attend_chunk(
    params: dict,
    x: Array,
    layer_cache: dict,
    start: Array,
    cfg: ArchConfig,
    *,
    block_tables: Optional[Array] = None,
    prefix_bucket: Optional[int] = None,
    backend: str = "auto",
    interpret: bool = False,
    shard=None,
):
    """Chunked-prefill step: C prompt tokens against the quantized cache.

    x: (B, C, D) — the chunk, at absolute positions ``start .. start+C-1``
    (``start`` is a traced scalar; every row of the call is at the same
    offset — the engine prefills one slot at a time, B == 1).

    The chunk's K/V are quantized and written into the cache first, then the
    chunk queries attend over the int8 cache with a causal-within-chunk mask
    (col <= start + row) via `kops.chunk_attention`. Unlike full prefill
    (which attends in bf16 and quantizes after), the chunk attends over the
    already-quantized prefix — that is the price of resuming a prefill
    mid-prompt; numerics match the decode path, not the one-shot prefill
    path. The attention cost is O(prefix), not O(S = max_len): on TPU the
    prefix-clamped Pallas kernel (`kernels/chunk_attn.py`) fetches and
    computes only the ``ceil((start+C)/block_s)`` S-blocks covering the
    valid prefix (scalar-prefetched ``start`` clamps the index maps), and
    off-TPU the XLA fallback slices the cache to the static
    ``prefix_bucket`` (the engine passes its power-of-two rounding of
    ``start + C``) — O(bucket) even without a kernel.

    ``block_tables`` ((B, max_blocks) int32) switches to the **paged**
    cache: layer_cache leaves are BlockPool arrays ((N_phys, KVH, page, D)
    values / (N_phys, KVH, page) scales) and the chunk's KV write resolves
    every position ``start+t`` through the table — logical block
    ``(start+t) // page`` → physical pool block — as one advanced-index
    scatter. The engine pre-maps every block covering ``start + C`` before
    the compiled step runs, so the scatter never lands in TRASH and the
    kernel's index maps only meet mapped blocks.

    Returns (out (B, C, D'), updated layer_cache).
    """
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim
    positions = jnp.broadcast_to(start + jnp.arange(c, dtype=jnp.int32),
                                 (b, c))
    q, k, v = _project_qkv(
        params, x, cfg, positions, backend=backend, interpret=interpret,
        shard=shard,
    )
    kq, ks, vq, vs = quantize_kv_cached(k, v)  # (B,KVH,C,D) / (B,KVH,C)

    if block_tables is not None:
        # paged write: scatter the whole chunk into its mapped pool blocks
        # (advanced-index scatter over (phys, kvh, offset) per token)
        page = layer_cache["k"].shape[2]
        pos_t = (start + jnp.arange(c)).astype(jnp.int32)  # (C,)
        phys = jnp.take(block_tables.astype(jnp.int32), pos_t // page,
                        axis=1)  # (B, C)
        i0 = phys[:, None, :]  # (B, 1, C)
        i1 = jnp.arange(layer_cache["k"].shape[1])[None, :, None]  # (1,KVH,1)
        i2 = (pos_t % page)[None, None, :]  # (1, 1, C)

        def write(cache, val, axis):
            del axis
            return cache.at[i0, i1, i2].set(val.astype(cache.dtype))
    else:
        def write(cache, val, axis):
            return jax.lax.dynamic_update_slice_in_dim(cache, val, start,
                                                       axis=axis)

    new_cache = {
        "k": write(layer_cache["k"], kq, 2),
        "k_scale": write(layer_cache["k_scale"],
                         ks.astype(layer_cache["k_scale"].dtype), 2),
        "v": write(layer_cache["v"], vq, 2),
        "v_scale": write(layer_cache["v_scale"],
                         vs.astype(layer_cache["v_scale"].dtype), 2),
    }
    out = kops.chunk_attention(
        q,
        new_cache["k"],
        new_cache["v"],
        new_cache["k_scale"],
        new_cache["v_scale"],
        start=start,
        block_tables=block_tables,
        prefix_bucket=prefix_bucket,
        backend=backend,
        interpret=interpret,
    )
    out = out.astype(x.dtype).reshape(b, c, cfg.n_heads * hd)
    out = apply_linear(out, params["wo"], backend=backend, interpret=interpret)
    return out, new_cache


# ---------------------------------------------------------------------------
# cross attention (VLM): queries from text stream, K/V from image embeddings
# ---------------------------------------------------------------------------


def cross_attend(
    params: dict,
    x: Array,
    context: Array,
    cfg: ArchConfig,
    *,
    backend: str = "auto",
    interpret: bool = False,
    unroll: bool = False,
    flash_block: int = 1024,
) -> Array:
    """x: (B, S, D) text; context: (B, T, D) image embeddings (stub frontend).
    No RoPE (positions are cross-modal); non-causal over context."""
    b, s, _ = x.shape
    t = context.shape[1]
    hd = cfg.resolved_head_dim
    q = apply_linear(x, params["wq"], backend=backend, interpret=interpret)
    k = apply_linear(context, params["wk"], backend=backend, interpret=interpret)
    v = apply_linear(context, params["wv"], backend=backend, interpret=interpret)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    out = kops.flash_attention(q, k, v, causal=False, backend=backend,
                               interpret=interpret, unroll=unroll,
                               block_q=flash_block, block_k=flash_block)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return apply_linear(out, params["wo"], backend=backend, interpret=interpret)

"""Vocab-sharded, sequence-chunked cross-entropy.

The (B, S, V) logits tensor for a 256k-vocab arch at train_4k is ~0.8 TB in
bf16 — it must never materialize. We scan over sequence chunks: each chunk
projects (B, C, D) @ (D, V) -> (B, C, V) (vocab tensor-sharded), reduces to
per-token loss, and the backward recomputes the chunk logits (jax.checkpoint
around the chunk body). Peak memory is one chunk of logits per device.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear

Array = jax.Array


def _chunk_loss(h_chunk: Array, labels_chunk: Array, w_head: Any,
                mask_chunk: Array, shard) -> tuple[Array, Array]:
    logits = apply_linear(h_chunk, w_head)  # (B, C, V)
    logits = shard(logits, "batch", None, "tensor").astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    nll = (logz - picked) * mask_chunk
    return jnp.sum(nll), jnp.sum(mask_chunk)


def xent_chunked(
    h: Array,  # (B, S, D) final hidden states
    w_head: Any,  # (D, V) lm head (dense or QuantLinear)
    labels: Array,  # (B, S) int32
    *,
    shard,
    n_chunks: int = 8,
    mask: Optional[Array] = None,
    unroll: bool = False,
) -> Array:
    """Mean next-token NLL. ``shard`` is ctx.shard (logical constraint fn)."""
    b, s, d = h.shape
    n_chunks = min(n_chunks, s)
    while s % n_chunks:
        n_chunks -= 1
    c = s // n_chunks
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    hc = h.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, c).transpose(1, 0, 2)
    mc = mask.reshape(b, n_chunks, c).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        hcb, lcb, mcb = xs
        loss_sum, n = jax.checkpoint(
            lambda a, b_, m_: _chunk_loss(a, b_, w_head, m_, shard)
        )(hcb, lcb, mcb)
        return (tot + loss_sum, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


def logits_last_token(h_last: Array, w_head: Any, shard) -> Array:
    """(B, 1, D) -> (B, 1, V) logits for sampling/eval at decode."""
    logits = apply_linear(h_last, w_head)
    return shard(logits, "batch", None, "tensor")

"""jax API compatibility shims.

The codebase targets current jax (`jax.shard_map`, `jax.sharding.AxisType`,
`pltpu.CompilerParams`); this module backfills the older spellings so the
same code runs on the container's pinned jax.
"""

from __future__ import annotations

from typing import Callable

import jax


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check: bool = False):
    """`jax.shard_map` when available, else the experimental spelling
    (`check` maps onto check_vma / check_rep respectively)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def tpu_compiler_params():
    """Pallas TPU CompilerParams class under its current or legacy name."""
    from jax.experimental.pallas import tpu as pltpu

    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types when the installed jax has
    explicit-sharding axis types; plain mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(axis_type.Auto,) * len(axes),
    )

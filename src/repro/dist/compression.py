"""int8 error-feedback gradient compression for the cross-pod all-reduce.

The pod axis rides DCN (slow inter-pod links), so the replicated-across-pods
regime compresses the gradient all-reduce: each leaf is quantized to int8
with a per-leaf absmax scale, psum'd across the given axes, and dequantized;
the local quantization residual is carried into the next step (error
feedback), so the *cumulative* contributed gradient is unbiased even though
every individual step is lossy.

API (used by `launch/train.py`):
  init_error_state(params)                    -> zero residual tree
  compressed_pmean(grads, err, mesh, axes)    -> (mean grads, new residuals)
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

QMAX = 127.0


def init_error_state(params: Any) -> Any:
    """Zero-initialized f32 residual tree matching ``params``."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g: Array, e: Array) -> tuple[Array, Array, Array]:
    """(grad + residual) -> (int8 values, f32 scale, new residual)."""
    gf = g.astype(jnp.float32) + e
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-30) / QMAX
    q = jnp.clip(jnp.round(gf / scale), -QMAX, QMAX).astype(jnp.int8)
    new_e = gf - q.astype(jnp.float32) * scale
    return q, scale, new_e


def compressed_pmean(grads: Any, err_state: Any, mesh, axes) -> tuple[Any, Any]:
    """int8-compressed mean of ``grads`` over the mesh ``axes``.

    Quantization (and the residual update) is local; only the int8 payload
    conceptually crosses the wire. The psum runs in a shard_map over the full
    mesh with replicated specs — gradients reaching this point are already
    sharded/replicated consistently by the outer jit, so the collective is
    purely the cross-``axes`` mean.
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    missing = [a for a in axes if a not in mesh.axis_names]
    if missing:
        raise ValueError(
            f"compressed_pmean axes {missing} not in mesh axes "
            f"{mesh.axis_names} — a silent skip here would return local "
            "gradients as if they were the cross-pod mean")
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err_state)
    if not axes or mesh.size == 1:
        out = []
        new_err = []
        for g, e in zip(leaves, err_leaves):
            q, scale, ne = _quantize_leaf(g, e)
            out.append(q.astype(jnp.float32) * scale)
            new_err.append(ne)
        return jax.tree.unflatten(treedef, out), \
            jax.tree.unflatten(treedef, new_err)

    qs, scales, new_err = [], [], []
    for g, e in zip(leaves, err_leaves):
        q, scale, ne = _quantize_leaf(g, e)
        qs.append(q)
        scales.append(scale)
        new_err.append(ne)

    def mean_fn(qs_, scales_):
        out = []
        for q, s in zip(qs_, scales_):
            deq = q.astype(jnp.float32) * s
            out.append(jax.lax.pmean(deq, axes))
        return tuple(out)

    from repro.dist.compat import shard_map

    n_in = len(qs)
    out = shard_map(
        mean_fn,
        mesh=mesh,
        in_specs=(tuple(P() for _ in range(n_in)),
                  tuple(P() for _ in range(n_in))),
        out_specs=tuple(P() for _ in range(n_in)),
        check=False,
    )(tuple(qs), tuple(scales))
    return jax.tree.unflatten(treedef, list(out)), \
        jax.tree.unflatten(treedef, new_err)

"""Logical->physical sharding rules (MaxText-style).

The model code annotates tensors with *logical* axis names ("batch", "seq",
"tensor", or None); `ShardingRules` maps each logical name onto zero or more
*physical* mesh axes. Defaults target the production meshes in
`repro.launch.mesh`:

  batch  -> ("pod", "data")   activations' leading dim (pure DP)
  fsdp   -> ("pod", "data")   weight rows (ZeRO-3 style parameter sharding)
  tensor -> "model"           heads / ff / vocab / experts-ff

``resolve(mesh)`` drops axes the mesh does not have (a host mesh has no
"pod"; a serve mesh may drop "fsdp" entirely — see `dryrun.rules_for`), so
the same rule object works on 1-device CPU, the 8-device test mesh, and the
256/512-chip production meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, tuple]


def _as_tuple(ax: Axes) -> tuple:
    if ax is None:
        return ()
    if isinstance(ax, str):
        return (ax,)
    return tuple(ax)


def axis_size(mesh: Optional[Mesh], ax: Axes) -> int:
    """Product of the mesh sizes of ``ax`` (axes absent from the mesh count
    as 1). ``ax`` may be None, a single axis name, or a tuple of names."""
    if mesh is None:
        return 1
    size = 1
    for a in _as_tuple(ax):
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> physical-mesh-axis mapping.

    Each field is None (replicate), one axis name, or a tuple of axis names
    (the composed axis shards over their product). ``seq`` defaults to
    replicated — sequence parallelism is an open item."""

    batch: Axes = ("pod", "data")
    fsdp: Axes = ("pod", "data")
    tensor: Axes = "model"
    seq: Axes = None

    def resolve(self, mesh: Optional[Mesh]) -> "ShardingRules":
        """Drop axes the mesh does not have; collapse singleton tuples to a
        bare name and empty tuples to None."""
        if mesh is None:
            return ShardingRules(batch=None, fsdp=None, tensor=None, seq=None)

        def keep(ax: Axes) -> Axes:
            present = tuple(a for a in _as_tuple(ax)
                            if a in mesh.shape and mesh.shape[a] > 1)
            if not present:
                return None
            if len(present) == 1:
                return present[0]
            return present

        return ShardingRules(
            batch=keep(self.batch), fsdp=keep(self.fsdp),
            tensor=keep(self.tensor), seq=keep(self.seq),
        )

    def physical(self, logical: Optional[str]) -> Axes:
        """Physical axes for one logical annotation (pre-`resolve` names)."""
        if logical is None:
            return None
        table = {"batch": self.batch, "seq": self.seq, "tensor": self.tensor,
                 "fsdp": self.fsdp}
        if logical not in table:
            raise ValueError(f"unknown logical axis {logical!r}")
        return table[logical]


def constraint(x: jax.Array, mesh: Optional[Mesh], rules: ShardingRules,
               *logical: Optional[str]) -> jax.Array:
    """`with_sharding_constraint` with logical names; no-op off-mesh.

    Dims whose size does not divide the mapped axis product fall back to
    replicated rather than erroring (tiny test configs on big meshes)."""
    if mesh is None or mesh.size <= 1:
        return x
    rules = rules.resolve(mesh)
    if len(logical) != x.ndim:
        raise ValueError(
            f"{len(logical)} logical axes for rank-{x.ndim} tensor")
    spec = []
    for dim, name in enumerate(logical):
        ax = rules.physical(name)
        n = axis_size(mesh, ax)
        spec.append(ax if (ax is not None and n > 1
                           and x.shape[dim] % n == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))

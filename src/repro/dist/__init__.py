"""Distribution utilities: logical->physical sharding rules and gradient
compression for the multi-pod training regime."""

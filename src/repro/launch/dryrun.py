"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent at production
scale without real hardware: 512 host-platform placeholder devices stand in
for 2 TPU v5e pods; every cell must .lower().compile() under GSPMD, and the
compiled artifact yields the memory/cost/collective numbers §Roofline reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2-pod mesh only
  ... --out benchmarks/results/dryrun.json
"""

# The VERY FIRST lines: jax locks the device count on first init, so the
# placeholder-device flag must be set before ANY other import pulls in jax.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro import optim  # noqa: E402
from repro.configs import (  # noqa: E402
    ARCH_NAMES,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    cell_is_runnable,
    get_config,
)
from repro.dist.sharding import ShardingRules, axis_size  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.blocks import ModelContext  # noqa: E402
from repro.models.quantized import QuantizeConfig, quantize_model  # noqa: E402
from repro.models.shardings import (  # noqa: E402
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
)

# per-arch training knobs at production scale (DESIGN.md §4)
_TRAIN_MICROBATCHES = {"grok-1-314b": 8, "llama-3.2-vision-90b": 4,
                       "qwen2-moe-a2.7b": 2}
_BF16_MOMENTS = {"grok-1-314b", "llama-3.2-vision-90b"}

# serve-path quantization for the dry-run: the paper's flagship W2*A8
_SERVE_QCFG = QuantizeConfig(w_bits=2, a_bits=8, bit_balance=True,
                             tensor_par=16)


def _sds(tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def rules_for(shape: ShapeConfig, mesh: Mesh) -> ShardingRules:
    rules = ShardingRules()
    if shape.kind in ("prefill", "decode") \
            and os.environ.get("REPRO_SERVE_FSDP", "0") != "1":
        # §Perf iteration 4 (serve sharding): weights tensor-parallel ONLY.
        # With fsdp-sharded weights the serve path contracts activations
        # against K-sharded weights and all-reduces int32 partials (measured:
        # 3×5.4 GB per projection on qwen3 prefill — the dominant collective).
        # TP-only weights fit per chip at serve time (largest: grok W2*A8
        # 118 GB/16 = 7.4 GB) and eliminate those collectives entirely.
        # REPRO_SERVE_FSDP=1 restores the baseline for A/B.
        rules = dataclasses.replace(rules, fsdp=None)
    dp = axis_size(mesh, rules.resolve(mesh).batch)
    if shape.global_batch % max(dp, 1) != 0:
        rules = dataclasses.replace(rules, batch=None)  # e.g. long_500k B=1
    return rules.resolve(mesh)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        }
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len cache
    tok = (b, 1, cfg.n_codebooks) if cfg.family == "audio" else (b, 1)
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    return {"tokens": jax.ShapeDtypeStruct(tok, jnp.int32), "cache": cache}


def probe_plan(cfg: ArchConfig) -> dict:
    """Depth schedule for the unrolled roofline probes.

    cost_analysis counts while-loop bodies once, so the full-depth compile
    under-reports FLOPs/bytes. Probes compile two reduced depths with EVERY
    scan unrolled; cost is exactly linear in the depth unit (identical
    layers), so total(g_real) = c(g1) + slope·(g_real−g1) is exact.
    """
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        rem = cfg.n_layers % every
        return {"unit": "group", "gs": (1, 2),
                "layers": (every + rem, 2 * every + rem),
                "g_real": cfg.n_layers // every}
    if cfg.family == "vlm":
        every = cfg.cross_attn_every
        return {"unit": "group", "gs": (1, 2),
                "layers": (every, 2 * every),
                "g_real": cfg.n_layers // every}
    return {"unit": "layer", "gs": (2, 4), "layers": (2, 4),
            "g_real": cfg.n_layers}


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               depth_override: Optional[int] = None,
               batch_override: Optional[int] = None,
               probe: bool = False):
    """Returns (jitted_fn, arg_structs) for one cell."""
    shape = SHAPES[shape_name]
    if batch_override:
        shape = dataclasses.replace(shape, global_batch=batch_override)
    tensor_par = axis_size(mesh, "model")
    cfg = get_config(arch)
    if depth_override:
        cfg = dataclasses.replace(cfg, n_layers=depth_override)
    cfg = cfg.with_kv_replication(tensor_par)
    rules = rules_for(shape, mesh)
    ctx = ModelContext(cfg=cfg, mesh=mesh, rules=rules, backend="xla",
                       remat=(shape.kind == "train"),
                       unroll=probe, flash_block=4096 if probe else 1024)

    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
    params_sp = param_pspecs(params_s, cfg, rules, mesh)
    params_sds = _sds(params_s, params_sp, mesh)

    if shape.kind == "train":
        from repro.launch.train import TrainConfig, make_train_step

        tcfg = TrainConfig(
            steps=10_000, global_batch=shape.global_batch,
            seq_len=shape.seq_len,
            microbatches=_TRAIN_MICROBATCHES.get(arch, 1),
            moment_dtype="bfloat16" if arch in _BF16_MOMENTS else None,
        )
        opt_cfg = optim.AdamWConfig(
            lr=3e-4, moment_dtype=tcfg.moment_dtype, grad_clip_norm=1.0)
        step_fn = make_train_step(cfg, tcfg, ctx, opt_cfg)
        opt_s = jax.eval_shape(lambda p: optim.init(p, opt_cfg), params_s)
        opt_sp = {
            "m": param_pspecs(opt_s["m"], cfg, rules, mesh),
            "v": param_pspecs(opt_s["v"], cfg, rules, mesh),
            "step": P(),
        }
        opt_sds = _sds(opt_s, opt_sp, mesh)
        batch_s = input_specs(cfg, shape)
        batch_sds = _sds(batch_s, batch_pspecs(batch_s, rules, mesh), mesh)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))

        fn = jax.jit(
            lambda p, o, b, st: step_fn(p, o, {}, b, st),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, batch_sds, step_sds)

    # serve cells run the ABQ-quantized model
    qparams_s = jax.eval_shape(
        lambda p: quantize_model(p, cfg, _SERVE_QCFG), params_s)
    qparams_sp = param_pspecs(qparams_s, cfg, rules, mesh)
    qparams_sds = _sds(qparams_s, qparams_sp, mesh)

    if shape.kind == "prefill":
        batch_s = input_specs(cfg, shape)
        batch_sds = _sds(batch_s, batch_pspecs(batch_s, rules, mesh), mesh)

        def prefill_fn(qp, batch):
            return lm.prefill(qp, batch["tokens"], cfg, ctx,
                              max_len=shape.seq_len,
                              image_embeds=batch.get("image_embeds"))

        return jax.jit(prefill_fn), (qparams_sds, batch_sds)

    # decode
    specs = input_specs(cfg, shape)
    cache_sp = cache_pspecs(specs["cache"], cfg, rules, mesh)
    cache_sds = _sds(specs["cache"], cache_sp, mesh)
    tok_sds = _sds(specs["tokens"],
                   batch_pspecs({"t": specs["tokens"]}, rules, mesh)["t"],
                   mesh)

    def decode_fn(qp, cache, tokens):
        return lm.decode_step(qp, cache, tokens, cfg, ctx)

    return jax.jit(decode_fn, donate_argnums=(1,)), (qparams_sds, cache_sds,
                                                     tok_sds)


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'bf16[64,128]{1,0}' -> bytes. Returns 0 for unparsable/token types."""
    import re

    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", type_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the compiled
    (post-SPMD-partitioning) module, by collective kind."""
    import re

    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        ret_type, opname = m.group(1), m.group(2)
        base = opname.replace("-start", "")
        if base not in _COLLECTIVES:
            continue
        if opname.endswith("-done"):
            continue
        # return type may be a tuple: (bf16[...], bf16[...])
        types = re.findall(r"[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?", ret_type)
        out[base] += sum(_shape_bytes(t) for t in types)
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def tpu_artifact_bytes(hlo_text: str, min_bytes: int = 32 * 2**20,
                       decode: bool = False) -> float:
    """Bytes in the compiled-for-CPU module that a TPU execution does not
    pay, so §Roofline can subtract them (conservatively: output-writes only):

      A. ``convert`` ops reading s8 -> s32/f32 (XLA:CPU materializes int8 dot
         operands as int32; the TPU MXU consumes int8 natively);
      B. big s8/s32 ``copy``/``concatenate``/``slice``/``dynamic-update-slice``
         (unrolled-scan cache threading — buffer donation + in-place DUS
         elide these on TPU; the real write is one token);
      C. ``fusion`` ops producing s32 tensors at cache scale (the fused form
         of A).

    Only ops >= min_bytes count (small converts are real epilogue work).
    """
    import re

    total = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*?\)?)\s+"
                     r"([\w\-]+)\((.*)$", ls)
        if not m:
            continue
        ret, op, operands = m.group(1), m.group(2), m.group(3)
        types = re.findall(r"[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?", ret)
        out_b = sum(_shape_bytes(t) for t in types)
        if out_b < min_bytes:
            continue
        if op == "convert" and ret.lstrip().startswith(("s32", "f32")) \
                and "s8[" in operands:
            total += out_b
        elif op in ("copy", "concatenate", "slice", "dynamic-update-slice") \
                and ret.lstrip().startswith(("s8", "s32")):
            total += out_b
        elif op == "fusion" and ret.lstrip().startswith("s32"):
            total += out_b
        elif decode and op == "fusion" and ret.lstrip().startswith("s8"):
            # decode-only: big s8 fusions are cache-threading writes (the
            # real write is one token); prefill s8 fusions are the genuine
            # KV-quantization output and stay counted
            total += out_b
    return total


def run_probe(arch: str, shape_name: str, mesh: Mesh) -> dict:
    """Two reduced-depth fully-unrolled compiles -> exact per-depth-unit
    slopes for flops/bytes/collectives (see probe_plan docstring)."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    plan = probe_plan(cfg)
    dp = axis_size(mesh, rules_for(shape, mesh).batch) or 1
    mb = _TRAIN_MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1
    unit = dp * mb  # smallest batch divisible by dp AND the microbatch count
    b_probe = None
    if shape.global_batch > unit and shape.global_batch % unit == 0:
        b_probe = unit  # per-device cost is exactly linear in local batch
    out = {"unit": plan["unit"], "gs": list(plan["gs"]),
           "g_real": plan["g_real"],
           "batch_probe": b_probe or shape.global_batch,
           "batch_real": shape.global_batch,
           "flops": [], "bytes": [], "coll": [], "artifact_bytes": [],
           "compile_s": []}
    for depth in plan["layers"]:
        t0 = time.time()
        fn, arg_sds = build_cell(arch, shape_name, mesh,
                                 depth_override=depth,
                                 batch_override=b_probe, probe=True)
        with mesh:
            compiled = fn.lower(*arg_sds).compile()
        cost = compiled.cost_analysis()
        txt = compiled.as_text()
        coll = collective_bytes(txt)
        out["flops"].append(float(cost.get("flops", 0.0)))
        out["bytes"].append(float(cost.get("bytes accessed", 0.0)))
        out["coll"].append(float(sum(coll.values())))
        out["artifact_bytes"].append(
            tpu_artifact_bytes(txt, decode=(shape.kind == "decode")))
        out["compile_s"].append(round(time.time() - t0, 1))
    return out


def run_cell(arch: str, shape_name: str, mesh: Mesh, *,
             text_dir: Optional[str] = None, probes: bool = False) -> dict:
    t0 = time.time()
    fn, arg_sds = build_cell(arch, shape_name, mesh)
    with mesh:
        lowered = fn.lower(*arg_sds)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    if text_dir:
        os.makedirs(text_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh.devices.size}.hlo"
        with open(os.path.join(text_dir, fname), "w") as f:
            f.write(txt)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(np.asarray(mesh.devices).shape),
        "n_devices": int(mesh.devices.size),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "compile_seconds": round(time.time() - t0, 1),
        "status": "ok",
    }
    if probes:
        rec["probe"] = run_probe(arch, shape_name, mesh)
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="one arch (default: all)")
    p.add_argument("--shape", default=None, help="one shape (default: all)")
    p.add_argument("--multi-pod", action="store_true",
                   help="only the 2-pod mesh (default: both meshes)")
    p.add_argument("--single-pod", action="store_true")
    p.add_argument("--out", default="benchmarks/results/dryrun.json")
    p.add_argument("--hlo-dir", default=None,
                   help="dump per-cell compiled HLO text here")
    p.add_argument("--probes", action="store_true",
                   help="also run unrolled reduced-depth probes per cell "
                        "(exact roofline totals; single-pod recommended)")
    p.add_argument("--probes-only", action="store_true",
                   help="run ONLY the probes (full-cell numbers come from a "
                        "prior dryrun.json; merged by benchmarks.roofline)")
    p.add_argument("--include-llama", action="store_true",
                   help="also run the paper's llama-7b config")
    args = p.parse_args(argv)

    meshes = []
    if not args.multi_pod:
        meshes.append(make_production_mesh(multi_pod=False))
    if not args.single_pod:
        meshes.append(make_production_mesh(multi_pod=True))

    archs = [args.arch] if args.arch else [
        a for a in ARCH_NAMES if a != "llama-7b" or args.include_llama
    ]
    shapes = [args.shape] if args.shape else list(SHAPES)

    results = []
    for mesh in meshes:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                runnable, why = cell_is_runnable(cfg, SHAPES[shape_name])
                tag = f"{arch} × {shape_name} × {mesh.devices.size}d"
                if not runnable:
                    print(f"[dryrun] SKIP {tag}: {why}", flush=True)
                    results.append({"arch": arch, "shape": shape_name,
                                    "n_devices": int(mesh.devices.size),
                                    "status": why})
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    if args.probes_only:
                        t0 = time.time()
                        rec = {"arch": arch, "shape": shape_name,
                               "n_devices": int(mesh.devices.size),
                               "status": "ok",
                               "probe": run_probe(arch, shape_name, mesh)}
                        print(f"[dryrun] PROBE {tag}: "
                              f"{rec['probe']['compile_s']}s", flush=True)
                    else:
                        rec = run_cell(arch, shape_name, mesh,
                                       text_dir=args.hlo_dir,
                                       probes=args.probes)
                        print(f"[dryrun] OK  {tag}: "
                              f"flops/dev={rec['flops_per_device']:.3e} "
                              f"bytes/dev={rec['bytes_per_device']:.3e} "
                              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                              f"args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
                              f"({rec['compile_seconds']}s)", flush=True)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "n_devices": int(mesh.devices.size),
                           "status": f"FAILED: {e}"}
                    print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                results.append(rec)
                # incremental write so long probe runs are resumable/partial
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_fail = sum(1 for r in results
                 if str(r.get("status", "")).startswith("FAILED"))
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, "
          f"{len(results) - n_ok - n_fail} skipped -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Batched quantized serving (the paper's deployment regime, Fig. 4b).

`Server` owns a quantized model and exposes two decode paths over it:

* **static batcher** (``generate(..., engine=False)``, the default and the
  baseline `benchmarks/bench_serving.py` measures against): right-pad the
  prompts, prefill once (per-row ``last_pos``: each row's first token comes
  from its true prompt end, and per-row decode positions keep short rows
  off the pad KV), then decode every sequence in lockstep as ONE jitted
  `lax.scan` over `lm.decode_step` (`lm.generate_tokens`) — tokens
  accumulate on device and cross to the host exactly once per call. A
  finished row (``eos_id``) freezes in place but its slot keeps burning
  decode steps until the longest row is done.

* **continuous batching** (``engine=True``, the production path): the call
  becomes a thin wrapper over `repro.serving.Engine` — submit every prompt,
  drain the step loop. The engine admits requests into free cache rows
  between device steps, retires rows on EOS/max-tokens with immediate slot
  reuse, and decodes ragged per-row positions in one compiled step; see
  `repro.serving.engine` for the slot/cache contract. Use `Server.engine`
  directly for streaming / per-request sampling params / arrival-driven
  workloads.

Inside each decode step, every quantized linear runs the fused ReQuant+GEMM
kernel (`kernels/abq_fused.py`) with decode-autotuned tiles — the serving
hot path of the whole repo.

CLI: PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.sharding import ShardingRules
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model, quantized_bytes


class Server:
    def __init__(self, arch: str, *, smoke: bool = True, w_bits: int = 2,
                 a_bits: int = 8, max_len: int = 256,
                 mesh=None, rules=None, params=None, seed: int = 0):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.ctx = ModelContext(cfg=self.cfg, mesh=mesh,
                                rules=rules or ShardingRules())
        self.max_len = max_len
        key = jax.random.PRNGKey(seed)
        fp_params = params if params is not None else lm.init_params(key, self.cfg)
        self.qcfg = QuantizeConfig(w_bits=w_bits, a_bits=a_bits,
                                   bit_balance=(w_bits <= 3))
        self.params = quantize_model(fp_params, self.cfg, self.qcfg)
        self.weight_mb = quantized_bytes(self.params) / 1e6
        # n_steps, top_k, top_p and eos are static (scan length / lax.top_k
        # width / python-level filter & done-mask structure); jit
        # re-specializes per value. key=None (greedy) is a static pytree
        # structure, so greedy and sampling get separate specializations.
        self._generate = jax.jit(
            lambda qp, c, t, n, key, temp, top_k, top_p, eos: \
                lm.generate_tokens(
                    qp, c, t, n, self.cfg, self.ctx, key=key,
                    temperature=temp, top_k=top_k, top_p=top_p, eos_id=eos),
            static_argnums=(3, 6, 7, 8),
        )
        # prefill jitted per (batch, prompt_len) shape — the eager path
        # re-dispatched op by op on every call, dominating short-request
        # serving; the engine's admit-prefill is jitted, so the static
        # baseline must be too for policy comparisons to mean anything.
        # Per-row ``last_pos`` picks each prompt's true last-token logits
        # (short rows of a ragged batch used to sample their first token
        # from the right-pad tail position)
        self._prefill = jax.jit(
            lambda qp, toks, last_pos: lm.prefill(
                qp, toks, self.cfg, self.ctx,
                max_len=self.max_len, last_pos=last_pos))
        self._sample_calls = 0
        self._engine = None
        self._engine_config = None

    def engine(self, *, n_slots: int = 4, fresh: bool = False, **kw):
        """The continuous-batching `repro.serving.Engine` over this
        server's quantized params. Built lazily and reused across calls
        while the requested configuration matches; a different
        configuration (or ``fresh=True``) rebuilds — silently handing back
        an engine with the wrong slot count/horizon would be worse than
        the recompile."""
        from repro.serving.engine import Engine

        config = dict(kw, n_slots=n_slots)
        if self._engine is None or fresh or config != self._engine_config:
            self._engine = Engine(self.params, self.cfg, self.ctx,
                                  max_len=self.max_len, **config)
            self._engine_config = config
        return self._engine

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 32,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, top_p: float = 0.0,
                 seed: Optional[int] = None, eos_id: Optional[int] = None,
                 engine: bool = False):
        """Prefill + scan-decode. ``greedy=False`` temperature/top-k/top-p
        samples (the PRNG key rides the scan carry — see
        `lm.generate_tokens`); ``seed`` pins the stream, else each call
        advances an internal counter. ``eos_id`` freezes finished rows in
        the jitted step and trims outputs after the stop token. Output
        tokens make exactly ONE device→host transfer.

        ``engine=True`` routes the call through the continuous-batching
        engine instead (submit-all + drain): greedy token outputs are
        bitwise identical to the static path (sampled streams differ —
        per-request fold_in keys vs the static scan's shared key), but
        finished rows are retired and their slots reused instead of
        burning lockstep steps — and one host sync per step rather than
        per call. The stats dict differs: engine scheduling stats
        (steps/occupancy) replace the static path's prefill/decode split;
        weight_mb/qtag are carried over.
        """
        if engine:
            # reuse whatever engine the caller configured (never silently
            # rebuild over queued work); default to an 8-slot one otherwise
            eng = self._engine if self._engine is not None \
                else self.engine(n_slots=8)
            outs, stats = eng.generate(
                prompts, max_new_tokens=max_new_tokens, greedy=greedy,
                temperature=temperature, top_k=top_k, top_p=top_p,
                seed=seed, eos_id=eos_id)
            stats["weight_mb"] = self.weight_mb
            stats["qtag"] = self.qcfg.tag()
            return outs, stats
        cfg, ctx = self.cfg, self.ctx
        b = len(prompts)
        plen = max(len(q) for q in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, q in enumerate(prompts):
            toks[i, : len(q)] = q  # right-padded; mask via per-seq length
        tokens = jnp.asarray(toks)
        lengths = np.asarray([len(q) for q in prompts], np.int32)

        t0 = time.time()
        logits, cache = self._prefill(self.params, tokens, lengths - 1)
        # ragged lockstep: each row decodes from ITS prompt end (per-row
        # pos → per-row RoPE/KV-write/attention-length downstream), so a
        # short row neither attends the pad KV nor conditions on it — the
        # same contract as the engine path
        cache["pos"] = jnp.asarray(lengths)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        if greedy:
            first = jnp.argmax(logits, -1).astype(jnp.int32)
            key = None
        else:
            if seed is None:
                seed = self._sample_calls
                self._sample_calls += 1
            key = jax.random.PRNGKey(seed)
            key, sub = jax.random.split(key)
            first = lm.sample_logits(logits, sub, temperature=temperature,
                                     top_k=top_k, top_p=top_p,
                                     vocab_size=cfg.vocab_size)
        t0 = time.time()
        gen, cache = self._generate(self.params, cache, first, max_new_tokens,
                                    key, jnp.asarray(temperature, jnp.float32),
                                    top_k, float(top_p), eos_id)
        gen_np = np.asarray(gen)  # the one device→host transfer
        t_decode = time.time() - t0

        # gen_np: (steps, B, 1) or audio (steps, B, 1, n_cb) — report the
        # first codebook for audio, matching the per-step loop this replaced.
        if gen_np.ndim == 4:
            gen_np = gen_np[..., 0]
        outs = [gen_np[:, i, 0].tolist() for i in range(b)]
        if eos_id is not None:
            # frozen tail after the stop token (see lm.generate_tokens) is
            # an artifact of the rectangular scan output — trim it
            outs = [o[: o.index(eos_id) + 1] if eos_id in o else o
                    for o in outs]

        stats = {
            "prefill_tok_s": b * plen / max(t_prefill, 1e-9),
            "decode_tok_s": b * max_new_tokens / max(t_decode, 1e-9),
            "weight_mb": self.weight_mb,
            "qtag": self.qcfg.tag(),
        }
        return outs, stats


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--w-bits", type=int, default=2)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args(argv)
    server = Server(arch=args.arch, smoke=args.smoke, w_bits=args.w_bits)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=16).tolist()
               for _ in range(args.batch)]
    outs, stats = server.generate(prompts, max_new_tokens=args.gen)
    print(stats)


if __name__ == "__main__":
    main()

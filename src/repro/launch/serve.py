"""Batched quantized serving (the paper's deployment regime, Fig. 4b).

`Server` owns a quantized model and a decode cache; `generate` batches
variable-length prompts (left-padded... we right-pad and track lengths),
prefills once, then decodes all sequences in lockstep — the standard static
batcher. Production continuous batching would slot new requests into free
cache rows between steps; the cache layout here (batch-major, pos-indexed)
supports that, and `admit` shows the hook.

Decode is ONE jitted `lax.scan` over `lm.decode_step`
(`lm.generate_tokens`): tokens accumulate on device and cross to the host
exactly once per `generate` call, instead of a Python step loop with a
per-token `int(...)` sync. Inside each step, every quantized linear runs
the fused ReQuant+GEMM kernel (`kernels/abq_fused.py`) with
decode-autotuned tiles — the serving hot path of the whole repo.

CLI: PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.sharding import ShardingRules
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.quantized import QuantizeConfig, quantize_model, quantized_bytes


class Server:
    def __init__(self, arch: str, *, smoke: bool = True, w_bits: int = 2,
                 a_bits: int = 8, max_len: int = 256,
                 mesh=None, rules=None, params=None, seed: int = 0):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.ctx = ModelContext(cfg=self.cfg, mesh=mesh,
                                rules=rules or ShardingRules())
        self.max_len = max_len
        key = jax.random.PRNGKey(seed)
        fp_params = params if params is not None else lm.init_params(key, self.cfg)
        self.qcfg = QuantizeConfig(w_bits=w_bits, a_bits=a_bits,
                                   bit_balance=(w_bits <= 3))
        self.params = quantize_model(fp_params, self.cfg, self.qcfg)
        self.weight_mb = quantized_bytes(self.params) / 1e6
        # n_steps and top_k are static (scan length / lax.top_k width); jit
        # re-specializes per value. key=None (greedy) is a static pytree
        # structure, so greedy and sampling get separate specializations.
        self._generate = jax.jit(
            lambda qp, c, t, n, key, temp, top_k: lm.generate_tokens(
                qp, c, t, n, self.cfg, self.ctx, key=key,
                temperature=temp, top_k=top_k),
            static_argnums=(3, 6),
        )
        self._sample_calls = 0

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 32,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, seed: Optional[int] = None):
        """Prefill + scan-decode. ``greedy=False`` temperature/top-k samples
        (the PRNG key rides the scan carry — see `lm.generate_tokens`);
        ``seed`` pins the stream, else each call advances an internal
        counter. Output tokens make exactly ONE device→host transfer."""
        cfg, ctx = self.cfg, self.ctx
        b = len(prompts)
        plen = max(len(q) for q in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, q in enumerate(prompts):
            toks[i, : len(q)] = q  # right-padded; mask via per-seq length
        tokens = jnp.asarray(toks)

        t0 = time.time()
        logits, cache = lm.prefill(self.params, tokens, cfg, ctx,
                                   max_len=self.max_len)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        if greedy:
            first = jnp.argmax(logits, -1).astype(jnp.int32)
            key = None
        else:
            if seed is None:
                seed = self._sample_calls
                self._sample_calls += 1
            key = jax.random.PRNGKey(seed)
            key, sub = jax.random.split(key)
            first = lm.sample_logits(logits, sub, temperature=temperature,
                                     top_k=top_k,
                                     vocab_size=cfg.vocab_size)
        t0 = time.time()
        gen, cache = self._generate(self.params, cache, first, max_new_tokens,
                                    key, jnp.asarray(temperature, jnp.float32),
                                    top_k)
        gen_np = np.asarray(gen)  # the one device→host transfer
        t_decode = time.time() - t0

        # gen_np: (steps, B, 1) or audio (steps, B, 1, n_cb) — report the
        # first codebook for audio, matching the per-step loop this replaced.
        if gen_np.ndim == 4:
            gen_np = gen_np[..., 0]
        outs = [gen_np[:, i, 0].tolist() for i in range(b)]

        stats = {
            "prefill_tok_s": b * plen / max(t_prefill, 1e-9),
            "decode_tok_s": b * max_new_tokens / max(t_decode, 1e-9),
            "weight_mb": self.weight_mb,
            "qtag": self.qcfg.tag(),
        }
        return outs, stats


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--w-bits", type=int, default=2)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args(argv)
    server = Server(arch=args.arch, smoke=args.smoke, w_bits=args.w_bits)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, server.cfg.vocab_size, size=16).tolist()
               for _ in range(args.batch)]
    outs, stats = server.generate(prompts, max_new_tokens=args.gen)
    print(stats)


if __name__ == "__main__":
    main()

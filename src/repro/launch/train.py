"""Distributed training driver.

`make_train_step` builds the jit'd step for a (cfg, mesh, rules) triple:
  * remat'd loss (models/lm.py), microbatch gradient accumulation via
    lax.scan when cfg asks for it,
  * AdamW with dtype-configurable moments (bf16 at ≥90B — DESIGN.md §4),
  * optional int8 error-feedback gradient compression across the pod axis
    (dist/compression.py) for the replicated-across-pods regime.

`run` is the CLI entry (python -m repro.launch.train --arch ... --steps ...)
used by examples and the fault-tolerance supervisor; it wires the
deterministic data pipeline, async checkpointing, straggler detection, and
resume-from-latest.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.ckpt import checkpoint as ckpt
from repro.configs import ArchConfig, get_config, get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist import compression
from repro.dist.sharding import ShardingRules
from repro.models import lm
from repro.models.blocks import ModelContext
from repro.models.shardings import batch_pspecs, param_pspecs

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 20
    microbatches: int = 1  # >1: lax.scan gradient accumulation
    moment_dtype: Optional[str] = None  # "bfloat16" at very large scale
    grad_clip: float = 1.0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    grad_compression: bool = False  # int8 EF all-reduce across "pod"
    seed: int = 0
    n_loss_chunks: int = 8
    straggler_factor: float = 3.0  # step slower than factor×median -> flag


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, ctx: ModelContext,
                    opt_cfg: optim.AdamWConfig):
    """Returns jit-able fn(params, opt_state, batch, step) -> (params, opt,
    metrics)."""

    def loss_of(params, batch):
        loss, metrics = lm.loss_fn(params, batch, cfg, ctx,
                                   n_loss_chunks=tcfg.n_loss_chunks)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        mb = tcfg.microbatches

        def reshape(x):
            b = x.shape[0]
            return x.reshape((mb, b // mb) + x.shape[1:])

        batches = jax.tree.map(reshape, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mbatch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(body, (zero, jnp.zeros(())),
                                           batches, unroll=ctx.unroll)
        grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32), gsum)
        loss = loss_sum / mb
        return loss, {"loss": loss}, grads

    def step_fn(params, opt_state, err_state, batch, step):
        loss, metrics, grads = compute_grads(params, batch)
        if tcfg.grad_compression and ctx.mesh is not None \
                and "pod" in ctx.mesh.axis_names:
            grads, err_state = compression.compressed_pmean(
                grads, err_state, ctx.mesh, ("pod",))
        lr = optim.cosine_with_warmup(
            step, base_lr=tcfg.lr, warmup=tcfg.warmup, total=tcfg.steps)
        new_params, new_opt = optim.update(
            grads, opt_state, params, opt_cfg, lr_scale=lr / opt_cfg.lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = optim.global_norm(grads)
        metrics["lr"] = lr
        return new_params, new_opt, err_state, metrics

    return step_fn


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    err_state: Any
    step: int


def init_state(key, cfg: ArchConfig, tcfg: TrainConfig,
               opt_cfg: optim.AdamWConfig) -> TrainState:
    params = lm.init_params(key, cfg)
    opt_state = optim.init(params, opt_cfg)
    err_state = (compression.init_error_state(params)
                 if tcfg.grad_compression else {})
    return TrainState(params, opt_state, err_state, 0)


class StragglerWatch:
    """Flags steps slower than factor × running median (per-host analogue of
    fleet-level straggler detection; on real pods this feeds the scheduler
    which re-slices the data feed away from the slow host)."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            slow = dt > self.factor * med
        self.times.append(dt)
        if slow:
            self.flagged.append(step)
        return slow


def run(argv: Optional[list[str]] = None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama-7b")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced smoke config (CPU-sized)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    p.add_argument("--checkpoint-every", type=int, default=25)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--data", type=int, default=1, help="data mesh axis")
    p.add_argument("--model", type=int, default=1, help="model mesh axis")
    p.add_argument("--fail-at-step", type=int, default=-1,
                   help="inject a crash at this step (fault-tolerance test)")
    p.add_argument("--grad-compression", action="store_true")
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr, microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        grad_compression=args.grad_compression,
    )
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(args.data, args.model)
    rules = ShardingRules().resolve(mesh)
    ctx = ModelContext(cfg=cfg, mesh=mesh if mesh.size > 1 else None,
                       rules=rules, remat=True)
    opt_cfg = optim.AdamWConfig(lr=tcfg.lr, weight_decay=0.0,
                                moment_dtype=tcfg.moment_dtype,
                                grad_clip_norm=tcfg.grad_clip)

    state = init_state(jax.random.PRNGKey(tcfg.seed), cfg, tcfg, opt_cfg)
    start = 0
    if args.resume:
        last = ckpt.latest_step(tcfg.checkpoint_dir)
        if last is not None:
            tree = {"params": state.params, "opt": state.opt_state}
            restored = ckpt.restore_like(tcfg.checkpoint_dir, last, tree)
            state = TrainState(restored["params"], restored["opt"],
                               state.err_state, last)
            start = last
            print(f"[train] resumed from step {last}")

    step_fn = make_train_step(cfg, tcfg, ctx, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))

    ds = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
        n_codebooks=cfg.n_codebooks))
    saver = ckpt.AsyncCheckpointer(tcfg.checkpoint_dir)
    watch = StragglerWatch(tcfg.straggler_factor)
    params, opt_state, err_state = state.params, state.opt_state, state.err_state
    losses = []
    try:
        for step in range(start, tcfg.steps):
            if step == args.fail_at_step:
                raise RuntimeError(f"[injected failure] at step {step}")
            batch_np = ds.batch(step, tcfg.global_batch)
            if cfg.family == "vlm":
                rng = np.random.default_rng(step)
                batch_np["image_embeds"] = rng.normal(
                    size=(tcfg.global_batch, cfg.n_image_tokens, cfg.d_model)
                ).astype(np.float32) * 0.02
            batch = jax.tree.map(jnp.asarray, batch_np)
            t0 = time.time()
            params, opt_state, err_state, metrics = jit_step(
                params, opt_state, err_state, batch, jnp.asarray(step))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = watch.record(step, dt)
            losses.append(loss)
            if step % 10 == 0 or step == tcfg.steps - 1:
                print(f"[train] step={step} loss={loss:.4f} dt={dt*1e3:.0f}ms"
                      + (" STRAGGLER" if slow else ""))
            if (step + 1) % tcfg.checkpoint_every == 0 or step == tcfg.steps - 1:
                saver.save(step + 1, {"params": params, "opt": opt_state})
    finally:
        # flush the in-flight async write even when a step raises — an
        # already-snapshotted checkpoint must land atomically so resume sees
        # the newest completed step, not a torn/missing directory.
        saver.wait()
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "straggler_steps": watch.flagged}


if __name__ == "__main__":
    run()

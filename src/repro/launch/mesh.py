"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure-DP (gradient all-reduce only) so it maps onto DCN between pods.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""

from __future__ import annotations

import jax

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return make_mesh((data, model), ("data", "model"))

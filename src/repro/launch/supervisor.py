"""Fault-tolerant training supervisor: run → crash → restore → continue.

On a real fleet this is the role of the cluster-level controller (Borg/K8s
restart policy + the job's own resume logic). Here the supervisor drives
``repro.launch.train.run`` in-process: any exception (including the
``--fail-at-step`` injected crash used by the tests) triggers a resume from
the latest complete checkpoint, up to ``max_restarts``. Because the data
pipeline is (seed, step)-deterministic and checkpoints are atomic, the
post-restart loss trajectory is identical to an uninterrupted run.
"""

from __future__ import annotations

import traceback
from typing import Optional

from repro.launch import train as train_mod


def supervise(argv: list[str], *, max_restarts: int = 3) -> dict:
    attempts = 0
    base_argv = [a for a in argv]
    while True:
        try:
            resume_argv = base_argv + (["--resume"] if attempts else [])
            result = train_mod.run(resume_argv)
            result["restarts"] = attempts
            return result
        except Exception as e:  # noqa: BLE001 — any failure triggers restart
            attempts += 1
            print(f"[supervisor] run failed ({e!r}); "
                  f"restart {attempts}/{max_restarts}")
            traceback.print_exc()
            if attempts > max_restarts:
                raise
            # injected-failure flags only apply to the first attempt
            base_argv = [
                a for i, a in enumerate(base_argv)
                if not (a == "--fail-at-step"
                        or (i > 0 and base_argv[i - 1] == "--fail-at-step"))
            ]


if __name__ == "__main__":
    import sys

    supervise(sys.argv[1:])

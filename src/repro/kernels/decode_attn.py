"""Flash-decoding over the int8 KV cache as a Pallas TPU kernel.

The jnp decode-attention path (`ops.decode_attention` modes "int8"/"fold"/
"naive") is XLA-lowered: it materializes the (B, KVH, G, S) logits and probs
in HBM, always reads the full S-length cache (the masked tail is fetched and
then written off with -1e30), and round-trips the re-quantized probs. This
kernel is the flash-decoding form of the same math, built so the int8 cache
is streamed HBM→VMEM **exactly once per step** and nothing S-sized ever goes
back to HBM:

* **Grid** is (B·KVH, S/block_s): one program row per KV head, a sequential
  sweep over S-blocks. The G = H/KVH query rows of a KV head are batched
  into a single (G, D) MXU tile — GQA without a repeated cache read.
* **In-VMEM dequant**: the per-token k/v scales ride along as (1, block_s)
  f32 rows; the int8→float conversion happens on the VPU against the VMEM
  tile. No f32 copy of the cache (4x its bytes) is ever materialized.
* **Fully-integer BMMs** (the paper's int8 attention regime): q is
  re-quantized per row to int8 once per block and contracted against the
  int8 K tile on the MXU (int32 accumulate); the softmax probs are folded
  with v_scale and re-quantized per row for the int8 PV contraction.
* **Online softmax**: running (max, sum, acc) live in VMEM scratch across
  the S sweep (split-S partial reduction), exactly the FlashAttention-2
  state machine restricted to Sq = 1.
* **length-aware block skipping**: the valid prefix length is a
  scalar-prefetch operand. S-blocks entirely past ``length`` are skipped
  two ways: the kv index maps clamp the block index to the last valid
  block (consecutive identical indices → the pipeline issues no new DMA,
  the tail is never fetched) and ``pl.when`` guards the body (the tail is
  never computed either). The jnp paths read those bytes and mask them.

Semantics note: ``length == 0`` produces a zero output row (attention over
an empty prefix). The jnp paths degenerate to a uniform average over the
whole cache there (softmax of an all ``-1e30`` row); decode never hits this
(the current token is always written before attending), but the kernel's
convention is the defensible one and is pinned by a test.

Contracts (shared by the contiguous and paged entry points)
-----------------------------------------------------------

* **Grid layout**: ``(B·KVH, S/block_s)`` — axis 0 is "parallel" (every
  (batch, kv-head) row is independent), axis 1 is "arbitrary" (the S sweep
  carries the online-softmax state, so it must run in order on one core).
* **Scratch usage** (all VMEM, live across the S sweep of one grid row,
  re-initialized under ``pl.when(si == 0)``): ``m (G,1) f32`` running max,
  ``l (G,1) f32`` running sum, ``acc (G,D) f32`` running output, and the
  per-row re-quantized query ``qi (G,D) int8`` / ``qs (G,1) f32`` —
  computed once per row and reused for every S-block (q is S-invariant).
* **Scalar-prefetch contract**: index maps run ahead of the kernel body on
  the scalar core, so everything they read must be prefetched.
  ``len_ref (B·KVH,) int32`` drives the block skip: the kv index maps
  clamp the S-block index to the last valid block (consecutive identical
  indices → the pipeline issues no new DMA) and ``pl.when`` guards the
  body. The paged entry point prefetches a second operand,
  ``bt_ref (B·max_blocks,) int32`` — the flattened per-row block tables —
  and resolves ``(row, s_block)`` to a *physical* pool block inside the
  index map, so the flash-decode loop streams only mapped blocks and the
  scattered pool never needs to be gathered into a contiguous copy.

Paged mode (`decode_attention_paged_pallas`)
--------------------------------------------

The serving engine's paged allocator (`repro.serving.paged.BlockPool`)
stores the cache as a pool of ``page``-token physical blocks with per-slot
block tables instead of contiguous ``max_len`` rows. The kernel body is
**identical** — same math, same scratch, same skip — only the kv/scale
index maps change: logical S-block ``si`` maps to
``bt[row, si // per] * KVH + head`` (``per = page // block_s``), i.e. the
indirection is folded into the DMA descriptor generation on the scalar
core at zero cost to the compute loop. The length clamp becomes a
block-table length: S-blocks past the valid prefix clamp to the last
mapped block, so unmapped (TRASH) tail entries are neither fetched nor
computed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params
from repro.kernels.ref import requant_rows

Array = jax.Array

_NEG_INF = -1e30

_CompilerParams = tpu_compiler_params()


def _decode_attn_kernel(
    len_ref,  # scalar prefetch: (B*KVH,) int32 valid prefix lengths
    q_ref,  # (1, G, D) f32 (pre-scaled by 1/sqrt(D))
    k_ref,  # (1, BS, D) int8
    ks_ref,  # (1, BS) f32 per-token K scales
    v_ref,  # (1, BS, D) int8
    vs_ref,  # (1, BS) f32 per-token V scales
    o_ref,  # (1, G, D) out dtype
    m_ref,  # VMEM (G, 1) f32 running max
    l_ref,  # VMEM (G, 1) f32 running sum
    acc_ref,  # VMEM (G, D) f32 running output
    qi_ref,  # VMEM (G, D) int8 re-quantized q (computed once per row)
    qs_ref,  # VMEM (G, 1) f32 q dequant scales
    *,
    block_s: int,
    s_steps: int,
):
    bh = pl.program_id(0)
    si = pl.program_id(1)
    length = len_ref[bh]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # per-row int8 re-quantization of q, once per (batch, kv-head) row —
        # q is invariant across the S sweep, so it stays in VMEM scratch.
        # requant_rows is THE quantization core (see ref.py): same bitwise
        # container as every other quant path in the repo.
        q_i8, q_s = requant_rows(q_ref[0], 127.0)
        qi_ref[...] = q_i8
        qs_ref[...] = q_s

    # blocks entirely past the valid prefix: no compute (and, via the
    # clamped index maps, no fetch)
    @pl.when(si * block_s < length)
    def _body():
        # int8 QK BMM: the re-quantized q against the int8 K tile
        logits_i = jax.lax.dot_general(
            qi_ref[...], k_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (G, BS)
        # in-VMEM dequant: per-token K scale applied to the int32 logits
        logits = logits_i.astype(jnp.float32) * (qs_ref[...] * ks_ref[...])
        cols = si * block_s + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        valid = cols < length
        logits = jnp.where(valid, logits, _NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)

        # int8 PV BMM: fold the per-token V scale into the probs, re-quantize
        # per row, contract on the int8 unit, dequant the partial
        pv_f = jnp.where(valid, p * vs_ref[...], 0.0)  # (G, BS)
        p_amax = jnp.max(jnp.abs(pv_f), axis=-1, keepdims=True)
        p_s = jnp.maximum(p_amax, 1e-12) / 127.0
        p_i8 = jnp.clip(jnp.round(pv_f / p_s), -127, 127).astype(jnp.int8)
        pv_i = jax.lax.dot_general(
            p_i8, v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (G, D)
        acc_ref[...] = acc_ref[...] * alpha + pv_i.astype(jnp.float32) * p_s
        m_ref[...] = m_new

    @pl.when(si == s_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret"),
)
def decode_attention_pallas(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Array,
    v_scale: Array,
    *,
    scale: float,
    length: Array | None = None,
    block_s: int = 256,
    interpret: bool = False,
) -> Array:
    """Single-token attention over the int8 cache, one HBM pass.

    q:        (B, 1, H, D) float
    k_cache:  (B, KVH, S, D) int8 (attention-native layout)
    k_scale:  (B, KVH, S) f32 per-token-per-head dequant scales
    length:   (B,) int32 valid prefix length, or None for the full S
    block_s:  S-tile length; must divide S (use
              `tuning.best_decode_attn_block` for the roofline pick)

    Returns (B, 1, H, D) in q's dtype.
    """
    b, _, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh
    if s_len % block_s:
        raise ValueError(f"S={s_len} must tile by block_s={block_s}")
    s_steps = s_len // block_s

    # fold (B, KVH) into one grid axis; G query rows share one program row
    qt = (q.astype(jnp.float32) * scale).reshape(b * kvh, group, d)
    kt = k_cache.reshape(b * kvh, s_len, d)
    vt = v_cache.reshape(b * kvh, s_len, d)
    kst = k_scale.astype(jnp.float32).reshape(b * kvh, s_len)
    vst = v_scale.astype(jnp.float32).reshape(b * kvh, s_len)
    if length is None:
        lens = jnp.full((b * kvh,), s_len, jnp.int32)
    else:
        lens = jnp.repeat(length.astype(jnp.int32), kvh)

    def _clamp(si, lb_ref, bh):
        # last valid block for this row; revisiting it on tail iterations
        # means the mapped index never changes -> no tail DMA is issued
        n_blocks = jax.lax.div(lb_ref[bh] + block_s - 1, block_s)
        return jnp.minimum(si, jnp.maximum(n_blocks - 1, 0))

    def q_map(bh, si, lb_ref):
        return (bh, 0, 0)

    def kv_map(bh, si, lb_ref):
        return (bh, _clamp(si, lb_ref, bh), 0)

    def sc_map(bh, si, lb_ref):
        return (bh, _clamp(si, lb_ref, bh))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * kvh, s_steps),
        in_specs=[
            pl.BlockSpec((1, group, d), q_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
        ],
        out_specs=pl.BlockSpec((1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, d), jnp.int8),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, block_s=block_s, s_steps=s_steps,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, qt, kt, kst, vt, vst)
    return out.reshape(b, kvh, group, d).reshape(b, 1, h, d)


def _paged_decode_attn_kernel(len_ref, bt_ref, *refs, block_s, s_steps):
    """The contiguous kernel body verbatim: the block table is consumed
    entirely by the index maps (DMA descriptor generation on the scalar
    core); the compute loop never sees the indirection."""
    del bt_ref
    _decode_attn_kernel(len_ref, *refs, block_s=block_s, s_steps=s_steps)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret"),
)
def decode_attention_paged_pallas(
    q: Array,
    k_pool: Array,
    v_pool: Array,
    k_scale: Array,
    v_scale: Array,
    block_tables: Array,
    *,
    scale: float,
    length: Array,
    block_s: int | None = None,
    interpret: bool = False,
) -> Array:
    """Single-token attention over the *paged* int8 pool, one HBM pass.

    q:            (B, 1, H, D) float
    k_pool:       (N_phys, KVH, page, D) int8 — the BlockPool device
                  arrays (one layer's slice); row 0 is the TRASH block
    k_scale:      (N_phys, KVH, page) f32 per-token dequant scales
    block_tables: (B, max_blocks) int32 logical→physical block map
    length:       (B,) int32 valid prefix length (<= mapped coverage)
    block_s:      S-tile length; must divide ``page`` (default: ``page``)

    Logical sequence length is ``max_blocks * page``; the kv index maps
    resolve ``(block_table, s_block)`` via scalar prefetch so only mapped
    blocks stream HBM→VMEM. Returns (B, 1, H, D) in q's dtype — bitwise
    identical to `decode_attention_pallas` over the equivalent contiguous
    cache **at the same block_s** (pinned by tests/test_paged_kv.py; a
    different S-tile changes the online-softmax partition, which is
    numerically — not bitwise — equivalent).
    """
    b, _, h, d = q.shape
    n_phys, kvh, page = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    group = h // kvh
    nb = block_tables.shape[1]
    s_len = nb * page
    if block_s is None:
        block_s = page
    if page % block_s:
        raise ValueError(f"page={page} must tile by block_s={block_s}")
    per = page // block_s
    s_steps = s_len // block_s

    qt = (q.astype(jnp.float32) * scale).reshape(b * kvh, group, d)
    kt = k_pool.reshape(n_phys * kvh, page, d)
    vt = v_pool.reshape(n_phys * kvh, page, d)
    kst = k_scale.astype(jnp.float32).reshape(n_phys * kvh, page)
    vst = v_scale.astype(jnp.float32).reshape(n_phys * kvh, page)
    lens = jnp.repeat(length.astype(jnp.int32), kvh)
    bt = block_tables.astype(jnp.int32).reshape(-1)  # (B * max_blocks,)

    def _clamp(si, lb_ref, bh):
        n_blocks = jax.lax.div(lb_ref[bh] + block_s - 1, block_s)
        return jnp.minimum(si, jnp.maximum(n_blocks - 1, 0))

    def _resolve(bh, si, lb_ref, bt_ref):
        """(grid row, clamped s-block) -> (physical pool row, sub-block)."""
        sc = _clamp(si, lb_ref, bh)
        bi = jax.lax.div(bh, kvh)
        hi = jax.lax.rem(bh, kvh)
        phys = bt_ref[bi * nb + jax.lax.div(sc, per)]
        return phys * kvh + hi, jax.lax.rem(sc, per)

    def q_map(bh, si, lb_ref, bt_ref):
        return (bh, 0, 0)

    def kv_map(bh, si, lb_ref, bt_ref):
        row, j = _resolve(bh, si, lb_ref, bt_ref)
        return (row, j, 0)

    def sc_map(bh, si, lb_ref, bt_ref):
        row, j = _resolve(bh, si, lb_ref, bt_ref)
        return (row, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * kvh, s_steps),
        in_specs=[
            pl.BlockSpec((1, group, d), q_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
        ],
        out_specs=pl.BlockSpec((1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, d), jnp.int8),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_attn_kernel, block_s=block_s, s_steps=s_steps,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, bt, qt, kt, kst, vt, vst)
    return out.reshape(b, kvh, group, d).reshape(b, 1, h, d)

"""jit'd public wrappers for the kernels, with backend dispatch.

Every op has three interchangeable execution paths:

* ``pallas``  — the TPU kernel (`abq_matmul.py`, `abq_fused.py`,
  `act_quant.py`, `flash_attention.py`). Used on real TPU; exercised in
  tests via ``interpret=True``.
* ``xla``     — a pure-jnp implementation with the *same memory layout and
  math* (packed bit-planes in HBM, unpack-then-int-matmul, online-softmax
  chunked attention). This is what the multi-pod dry-run lowers, so
  cost_analysis/HLO reflect the technique's true bytes/FLOPs.
* ``ref``     — the tiny oracle in `ref.py` (tests only).

``backend='auto'`` picks pallas on TPU, xla elsewhere.

A/B toggles (all also take explicit keyword args that win over the env):

* ``REPRO_ABQ_FUSED`` ∈ {"1" (default), "0"} — "1" routes `abq_linear`
  through the fused ReQuant+GEMM kernel (`abq_fused.py`): the int8
  activation container never round-trips HBM between the quantizer and the
  GEMM. "0" restores the two-kernel act_quant → abq_matmul baseline.
* ``REPRO_DECODE_ATTN`` ∈ {"pallas" (default), "int8", "fold", "naive"} —
  decode-attention strategy (§Perf iterations; see `decode_attention`).
  "pallas" is the flash-decoding kernel over the int8 cache
  (`decode_attn.py`); it falls back to the jnp "int8" math off-TPU unless
  ``interpret`` is set.
* ``REPRO_CHUNK_ATTN`` ∈ {"pallas" (default), "xla", "naive"} —
  chunked-prefill attention strategy (`chunk_attention`). "pallas" is the
  prefix-clamped flash kernel over the int8 cache (`chunk_attn.py`);
  "xla" is the same blocked int8 math jnp-lowered with **prefix
  bucketing** (only the first ``prefix_bucket`` cache positions are
  sliced and streamed — O(bucket), not O(max_len), even off-TPU);
  "naive" is the original full-S dequantize-and-mask math kept for A/B.
  "pallas" falls back to "xla" off-TPU unless ``interpret`` is set.

Block sizes: when the caller does not pin (block_m, block_n, block_k), the
pallas paths ask `tuning.best_blocks` — a cached per-(M, K, N, w_bits)
roofline search — so prefill (large M) and decode (M = batch) each get
shape-appropriate tiles instead of one hardcoded config.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.quantizers import PackedWeight
from repro.kernels import ref as _ref
from repro.kernels import tuning
from repro.kernels.abq_fused import abq_linear_fused_pallas, fits_vmem
from repro.kernels.abq_matmul import abq_matmul_pallas
from repro.kernels.act_quant import act_quant_pallas
from repro.kernels.chunk_attn import (
    _fold_q,
    _unfold_o,
    chunk_attention_paged_pallas,
    chunk_attention_pallas,
)
from repro.kernels.decode_attn import (
    decode_attention_paged_pallas,
    decode_attention_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas

Array = jax.Array


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


# ---------------------------------------------------------------------------
# activation quantization (ReQuant)
# ---------------------------------------------------------------------------


def act_qmax(bits: int) -> float:
    """Container max |q| for a ``bits``-wide symmetric per-token grid.

    ====  ====  =======================================
    bits  qmax  grid
    ====  ====  =======================================
    8     127   int8 full range (±127; -128 unused)
    4     7     ±7
    3     3     ±3
    2     1     ternary {-1, 0, 1}
    1     1     binary sign container {-1, 0, 1}·scale
    ====  ====  =======================================

    General rule ``2^(bits-1) - 1``; 1-bit floors at 1.0 (a 0-level grid
    cannot represent anything) — the sign container the paper's W·A1
    configs use.
    """
    if not 1 <= bits <= 8:
        raise ValueError(f"activation bits must be in [1, 8], got {bits}")
    return max(float(2 ** (bits - 1) - 1), 1.0)


def act_quant(
    x: Array, bits: int = 8, backend: str = "auto", interpret: bool = False
) -> tuple[Array, Array]:
    """Per-token symmetric quantization of x[..., D] -> (int8, f32 scales)."""
    qmax = act_qmax(bits)
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    backend = _resolve(backend)
    if backend == "pallas":
        q, s = act_quant_pallas(x2, qmax=qmax, interpret=interpret)
    else:
        q, s = _ref.act_quant_ref(x2, qmax=qmax)
    return q.reshape(*lead, d), s.reshape(*lead, 1)


# ---------------------------------------------------------------------------
# arbitrary-bit GEMM
# ---------------------------------------------------------------------------


def _abq_matmul_xla(
    x_q: Array,
    x_scale: Array,
    pw: PackedWeight,
    out_dtype=jnp.bfloat16,
) -> Array:
    """XLA path — identical math to the Pallas kernel, jnp ops.

    The packed planes are unpacked to {0,1} int8 and contracted on the int8
    unit (preferred_element_type=int32). HLO bytes show the packed weight
    reads; HLO flops show the n_planes int matmuls — the roofline of the
    technique is visible to cost_analysis.
    """
    n_planes = pw.planes.shape[0]
    w_bits = bitplane.unpack_bitplanes(pw.planes, pw.k, dtype=jnp.int8)

    if pw.scale.ndim == 3:  # per-group g128: scale/zp are (G, 1, N)
        m = x_q.shape[0]
        n = pw.out_features
        g = pw.scale.shape[0]
        gs = pw.k // g
        xg = x_q[:, : pw.k].reshape(m, g, gs)
        wg = w_bits[:, : pw.k].reshape(n_planes, g, gs, n)
        acc = jnp.zeros((g, m, n), jnp.int32)
        for s in range(n_planes):
            part = jnp.einsum("mgk,gkn->gmn", xg, wg[s],
                              preferred_element_type=jnp.int32)
            acc = acc + (part << s)
        rowsum = jnp.sum(xg.astype(jnp.int32), axis=2).T[:, :, None]  # (G,M,1)
        out = jnp.sum(
            pw.scale * (acc.astype(jnp.float32) - pw.zero_point * rowsum),
            axis=0,
        )
        return (x_scale * out).astype(out_dtype)

    acc = jnp.zeros((x_q.shape[0], pw.out_features), jnp.int32)
    for s in range(n_planes):
        part = jax.lax.dot_general(
            x_q,
            w_bits[s],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << s)
    rowsum = jnp.sum(x_q.astype(jnp.int32), axis=1, keepdims=True)
    out = x_scale * (
        pw.scale * (acc.astype(jnp.float32) - pw.zero_point * rowsum)
    )
    return out.astype(out_dtype)


def _tuned_blocks(m: int, kp: int, n: int, pw: PackedWeight) -> tuple:
    """Cached autotuned (block_m, block_n, block_k) for one GEMM shape."""
    cand = tuning.best_blocks(m, kp, n, int(pw.planes.shape[0]))
    return cand.block_m, cand.block_n, cand.block_k


def _flatten_pad(x: Array, pw: PackedWeight) -> tuple[Array, tuple]:
    """Flatten leading dims and zero-pad the contraction to the planes'
    32-padded length; the one place the activation/weight K contract is
    enforced. Returns (x2 [M, Kp], lead_shape)."""
    lead = x.shape[:-1]
    kk = x.shape[-1]
    x2 = x.reshape(-1, kk)
    kp = bitplane.padded_k(pw.k)
    if kk != kp:
        if kk != pw.k:
            raise ValueError(f"activation K={kk} != weight K={pw.k}")
        x2 = jnp.pad(x2, ((0, 0), (0, kp - kk)))
    return x2, lead


def abq_matmul(
    x_q: Array,
    x_scale: Array,
    pw: PackedWeight,
    *,
    out_dtype=jnp.bfloat16,
    backend: str = "auto",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> Array:
    """Quantized GEMM: x_q int8 [..., K] × packed weight -> bf16 [..., N].

    Block sizes default to the `tuning.best_blocks` cache (decode shapes get
    small-M weight-stationary tiles, prefill gets MXU-saturating ones);
    passing any of them explicitly pins all three (missing ones take the
    legacy 128/128/512 defaults).
    """
    x2, lead = _flatten_pad(x_q, pw)
    s2 = x_scale.reshape(-1, 1)
    backend = _resolve(backend)
    if backend == "pallas":
        if block_m is None and block_n is None and block_k is None:
            block_m, block_n, block_k = _tuned_blocks(
                x2.shape[0], x2.shape[1], pw.out_features, pw)
        else:
            block_m = 128 if block_m is None else block_m
            block_n = 128 if block_n is None else block_n
            block_k = 512 if block_k is None else block_k
        out = abq_matmul_pallas(
            x2,
            s2,
            pw.planes,
            pw.scale,
            pw.zero_point,
            block_m=block_m,
            block_n=block_n,
            block_k=block_k,
            out_dtype=out_dtype,
            interpret=interpret,
        )
    else:
        out = _abq_matmul_xla(x2, s2, pw, out_dtype=out_dtype)
    return out.reshape(*lead, pw.out_features)


# ---------------------------------------------------------------------------
# fused ReQuant + GEMM (abq_linear)
# ---------------------------------------------------------------------------


def _fused_enabled() -> bool:
    val = os.environ.get("REPRO_ABQ_FUSED", "1")
    if val not in ("0", "1"):
        raise ValueError(
            f"REPRO_ABQ_FUSED must be '0' or '1', got {val!r}")
    return val == "1"


def _abq_linear_fused_xla(
    x: Array, pw: PackedWeight, qmax: float, out_dtype
) -> Array:
    """XLA mirror of the fused kernel: quantization inlined into the same
    jitted region as the bit-plane matmul, so XLA fuses the producer into
    the GEMM prologue — the int8 container is never a standalone HBM
    round-trip in the lowered module."""
    q, scale = _ref.requant_rows(x, qmax)
    return _abq_matmul_xla(q, scale, pw, out_dtype=out_dtype)


def abq_linear(
    x: Array,
    pw: PackedWeight,
    *,
    act_bits: int = 8,
    out_dtype=jnp.bfloat16,
    backend: str = "auto",
    interpret: bool = False,
    fused: Optional[bool] = None,
) -> Array:
    """ReQuant + ABQ GEMM: bf16 [..., K] -> bf16 [..., N].

    ``fused=None`` consults ``REPRO_ABQ_FUSED`` (default on): the ReQuant
    runs inside the GEMM kernel and the quantized activation stays in VMEM.
    The unfused two-kernel path remains for A/B and as the fallback when a
    full-K fused tile would not fit VMEM or the weight is per-group (g128)
    quantized.
    """
    if fused is None:
        fused = _fused_enabled()
    backend = _resolve(backend)
    qmax = act_qmax(act_bits)
    if fused and pw.scale.ndim != 3:  # g128 scales: unfused path only
        x2, lead = _flatten_pad(x, pw)
        kp = x2.shape[-1]
        if backend != "pallas":
            out = _abq_linear_fused_xla(x2, pw, qmax, out_dtype)
            return out.reshape(*lead, pw.out_features)
        bm, bn, _ = _tuned_blocks(x2.shape[0], kp, pw.out_features, pw)
        if fits_vmem(bm, kp, bn, int(pw.planes.shape[0]),
                     tuning.VMEM_BYTES // 4):
            out = abq_linear_fused_pallas(
                x2, pw.planes, pw.scale, pw.zero_point,
                qmax=qmax, block_m=bm, block_n=bn,
                out_dtype=out_dtype, interpret=interpret,
            )
            return out.reshape(*lead, pw.out_features)
        # fall through: K too large for a fused full-K tile

    x_q, x_scale = act_quant(x, bits=act_bits, backend=backend,
                             interpret=interpret)
    return abq_matmul(
        x_q, x_scale, pw, out_dtype=out_dtype, backend=backend,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

# decode-attention strategies (§Perf iterations, kept for A/B):
#   pallas — flash-decoding Pallas kernel over the int8 cache (iteration 4):
#            one HBM pass, VMEM online softmax, length-aware block skip
#   int8   — fully-integer QK/PV contractions, scales applied to logits/probs
#            (XLA-lowered; the non-TPU fallback for "pallas")
#   fold   — f32 contractions with the dequant scale folded out (iteration 1)
#   naive  — dequantize the cache to f32, then attend (baseline)
DECODE_ATTN_MODES = ("pallas", "int8", "fold", "naive")


def _flash_xla(
    q: Array,
    k: Array,
    v: Array,
    causal: bool,
    scale: float,
    q_offset: int,
    block_k: int = 1024,
    block_q: int = 1024,
    unroll: bool = False,
) -> Array:
    """Online-softmax chunked attention in pure jnp (lax.scan over KV blocks,
    lax.map over Q blocks). Same O(S) memory behaviour as the Pallas kernel —
    this is what the dry-run compiles, so prefill_32k does not materialize an
    S×S score tensor."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    orig_sq = sq
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q:
        pad = block_q - sq % block_q
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sq = q.shape[1]
    if skv % block_k:
        pad = block_k - skv % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_steps = k.shape[1] // block_k
    kb = k.reshape(b, kv_steps, block_k, kvh, d)
    vb = v.reshape(b, kv_steps, block_k, kvh, d)

    def one_q_block(args):
        qi, qblk = args  # qblk: (b, block_q, h, d)
        qf = qblk.astype(jnp.float32) * scale

        def body(carry, kv):
            m_prev, l_prev, acc = carry
            kv_i, kblk, vblk = kv
            kf = kblk.astype(jnp.float32)
            vf = vblk.astype(jnp.float32)
            # GQA without repeat: fold group into q-head axis
            qg = qf.reshape(b, block_q, kvh, group, d)
            s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kf)
            rows = qi * block_q + q_offset + jnp.arange(block_q)
            cols = kv_i * block_k + jnp.arange(k.shape[1] // kv_steps)
            if causal:
                mask = rows[:, None] >= cols[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, -1e30)
            # mask kv padding
            valid = cols < skv
            s = jnp.where(valid[None, None, None, None, :], s, -1e30)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vf)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, block_q, kvh, group), -1e30, jnp.float32)
        l0 = jnp.zeros((b, block_q, kvh, group), jnp.float32)
        a0 = jnp.zeros((b, block_q, kvh, group, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body,
            (m0, l0, a0),
            (jnp.arange(kv_steps), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4)),
            unroll=unroll,
        )
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out.reshape(b, block_q, h, d)

    q_blocks = q.reshape(b, sq // block_q, block_q, h, d).transpose(1, 0, 2, 3, 4)
    _, outs = jax.lax.scan(
        lambda _, xs: (None, one_q_block(xs)),
        None,
        (jnp.arange(sq // block_q), q_blocks),
        unroll=unroll,
    )
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return out[:, :orig_sq].astype(q.dtype)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,
    backend: str = "auto",
    interpret: bool = False,
    unroll: bool = False,
    block_q: int = 1024,
    block_k: int = 1024,
) -> Array:
    """q [B,Sq,H,D] × k/v [B,Skv,KVH,D] -> [B,Sq,H,D] (GQA, causal)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    backend = _resolve(backend)
    if backend == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset,
            interpret=interpret,
        )
    return _flash_xla(q, k, v, causal, scale, q_offset,
                      block_k=block_k, block_q=block_q, unroll=unroll)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
    *,
    scale: Optional[float] = None,
    length: Optional[Array] = None,
    block_tables: Optional[Array] = None,
    fused_dequant: Optional[bool] = None,
    backend: str = "auto",
    interpret: bool = False,
    block_s: Optional[int] = None,
) -> Array:
    """Single-token attention over a (possibly int8-quantized) KV cache.

    q:        [B, 1, H, D]
    k_cache:  [B, KVH, S, D] (int8 or bf16; attention-native layout,
              §Perf iteration 3 — no per-step transpose of the cache)
    k_scale:  [B, KVH, S] per-token-per-head dequant scales (if int8)
    length:   [B] valid prefix length (positions >= length are masked)

    **Paged mode** (``block_tables`` given): the cache operands are the
    serving engine's BlockPool arrays instead of contiguous rows —
    k/v [N_phys, KVH, page, D], scales [N_phys, KVH, page] — and
    ``block_tables`` [B, max_blocks] int32 maps each row's logical blocks
    to physical pool blocks (logical S = max_blocks * page). ``length`` is
    required (it is also the block-table valid length). The "pallas" mode
    resolves the indirection inside the kernel's scalar-prefetched index
    maps (`decode_attention_paged_pallas`) — only mapped blocks stream;
    the jnp fallbacks gather the mapped blocks into a contiguous
    [B, KVH, S, D] view first (XLA-lowered; same math, extra gather).

    Memory-bound op: the dominant bytes are the cache read.

    §Perf iteration 4 ("pallas", the default): the flash-decoding Pallas
    kernel (`kernels/decode_attn.py`) streams the int8 cache HBM→VMEM once
    per step — online softmax in VMEM scratch (no (B,KVH,G,S) logits/probs
    round-trip), per-token dequant on the VPU, int8 QK/PV MXU contractions,
    and ``length``-aware S-block skipping so the masked tail is never
    fetched. ``block_s`` defaults to `tuning.best_decode_attn_block`'s
    cache-bytes roofline pick. Off-TPU (and not ``interpret``) it falls
    back to the jnp "int8" path below, which is the same math XLA-lowered.

    fused_dequant=True (§Perf iteration 1): contract q directly against the
    int8 cache and apply the per-token scale to the (B,KVH,G,S) logits /
    fold v_scale into the probs — the f32 dequantized cache copy (4× the
    int8 bytes) never materializes. Exact same math: the scale is constant
    along the contracted D axis. fused_dequant=False keeps the naive
    dequant-then-attend path (the pre-iteration baseline, kept for A/B).

    Mode resolution: explicit ``fused_dequant`` (bool → "int8"/"naive",
    or a mode string) wins; otherwise the ``REPRO_DECODE_ATTN`` env var
    picks one of ``DECODE_ATTN_MODES`` ("pallas" default); anything else
    raises. An int8 cache with missing scales raises — silently attending
    over raw int8 container values is never meaningful.
    """
    mode = fused_dequant
    if mode is None:  # A/B toggle for §Perf iterations
        mode = os.environ.get("REPRO_DECODE_ATTN", "pallas")
    if mode is True:
        mode = "int8"
    elif mode is False:
        mode = "naive"
    if mode not in DECODE_ATTN_MODES:
        raise ValueError(
            f"decode_attention mode {mode!r} not in {DECODE_ATTN_MODES} "
            "(check REPRO_DECODE_ATTN)")
    if k_cache.dtype == jnp.int8 and (k_scale is None or v_scale is None):
        missing = "k_scale" if k_scale is None else "v_scale"
        raise ValueError(
            f"decode_attention: int8 KV cache but {missing} is None — the "
            "per-token dequant scales are required to interpret the int8 "
            "container (pass the scales quantize_kv_cached produced)")
    b, _, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)

    if block_tables is not None:
        if length is None:
            raise ValueError(
                "decode_attention: paged mode (block_tables) requires "
                "`length` — the block-table valid length drives both the "
                "mask and the kernel's block skip")
        page = k_cache.shape[2]
        if mode == "pallas" and k_cache.dtype == jnp.int8 \
                and (_resolve(backend) == "pallas" or interpret):
            kvh = k_cache.shape[1]
            s_log = block_tables.shape[1] * page
            if block_s is None:
                block_s = tuning.best_paged_decode_attn_block(
                    b, kvh, h // kvh, s_log, d, page).block_s
            return decode_attention_paged_pallas(
                q, k_cache, v_cache, k_scale, v_scale, block_tables,
                scale=scale, length=length, block_s=block_s,
                interpret=interpret)
        # jnp fallback: gather the mapped blocks into a contiguous view
        # (B, max_blocks, KVH, page, ...) -> (B, KVH, max_blocks*page, ...)
        def unpage(pool):
            g = pool[block_tables]
            if g.ndim == 5:
                return g.transpose(0, 2, 1, 3, 4).reshape(
                    g.shape[0], g.shape[2], -1, g.shape[4])
            return g.transpose(0, 2, 1, 3).reshape(
                g.shape[0], g.shape[2], -1)

        k_cache, v_cache = unpage(k_cache), unpage(v_cache)
        if k_scale is not None:
            k_scale, v_scale = unpage(k_scale), unpage(v_scale)

    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh

    if mode == "pallas" and k_cache.dtype == jnp.int8:
        # the Pallas kernel needs a real TPU lowering (or the interpreter);
        # elsewhere the jnp int8 path below is the same math, XLA-lowered
        if _resolve(backend) == "pallas" or interpret:
            if block_s is None:
                block_s = tuning.best_decode_attn_block(
                    b, kvh, group, s_len, d).block_s
            return decode_attention_pallas(
                q, k_cache, v_cache, k_scale, v_scale,
                scale=scale, length=length, block_s=block_s,
                interpret=interpret,
            )
        mode = "int8"

    qf = q.astype(jnp.float32).reshape(b, kvh, group, d) * scale

    if mode == "int8" and k_cache.dtype == jnp.int8 and k_scale is not None:
        # §Perf iteration 2: fully-integer QK and PV contractions — the int8
        # cache is contracted on the int8 unit (preferred int32), so no f32
        # copy of the cache (4× its bytes) ever materializes. q and the
        # v_scale-folded probs are quantized per row (the paper's int8
        # attention BMMs / FastTransformer regime).
        q_amax = jnp.max(jnp.abs(qf), axis=-1, keepdims=True)
        q_s = jnp.maximum(q_amax, 1e-8) / 127.0
        q_i8 = jnp.clip(jnp.round(qf / q_s), -127, 127).astype(jnp.int8)
        logits_i = jnp.einsum("bkgd,bksd->bkgs", q_i8, k_cache,
                              preferred_element_type=jnp.int32)
        k_s = k_scale[:, :, None, :]  # (b,kvh,1,s) — layout-native, no transpose
        logits = logits_i.astype(jnp.float32) * (q_s * k_s)
        if length is not None:
            valid = jnp.arange(s_len)[None, :] < length[:, None]
            logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        # fold v_scale into probs, re-quantize the folded probs per row
        v_s = v_scale[:, :, None, :]
        pf = probs * v_s
        p_amax = jnp.max(jnp.abs(pf), axis=-1, keepdims=True)
        p_s = jnp.maximum(p_amax, 1e-12) / 127.0
        p_i8 = jnp.clip(jnp.round(pf / p_s), -127, 127).astype(jnp.int8)
        out_i = jnp.einsum("bkgs,bksd->bkgd", p_i8, v_cache,
                           preferred_element_type=jnp.int32)
        out = out_i.astype(jnp.float32) * p_s
        return out.reshape(b, 1, h, d).astype(q.dtype)

    if mode == "fold" and k_scale is not None:
        # iteration 1 (kept for A/B): scale folded out of the contraction,
        # cache still converted to f32 (bytes unchanged — refuted hypothesis)
        logits = jnp.einsum("bkgd,bksd->bkgs", qf,
                            k_cache.astype(jnp.float32))
        logits = logits * k_scale[:, :, None, :]
    else:
        kf = k_cache.astype(jnp.float32)
        if k_scale is not None:
            kf = kf * k_scale[..., None]
        logits = jnp.einsum("bkgd,bksd->bkgs", qf, kf)

    if length is not None:
        valid = jnp.arange(s_len)[None, :] < length[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)

    if mode == "fold" and v_scale is not None:
        pscaled = probs * v_scale[:, :, None, :]
        out = jnp.einsum("bkgs,bksd->bkgd", pscaled,
                         v_cache.astype(jnp.float32))
    else:
        vf = v_cache.astype(jnp.float32)
        if v_scale is not None:
            vf = vf * v_scale[..., None]
        out = jnp.einsum("bkgs,bksd->bkgd", probs, vf)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked-prefill attention
# ---------------------------------------------------------------------------

# chunked-prefill attention strategies (kept for A/B):
#   pallas — prefix-clamped flash kernel over the int8 cache
#            (chunk_attn.py): one HBM pass over ceil((start+C)/block_s)
#            blocks, VMEM online softmax, int8 QK/PV MXU contractions
#   xla    — the SAME blocked int8 math jnp-lowered (bitwise-identical to
#            the kernel at equal tiling), with prefix bucketing: only the
#            first ``prefix_bucket`` cache positions are sliced/streamed
#   naive  — full-S dequantize-and-mask + plain softmax (the pre-kernel
#            attend_chunk math; O(max_len) per chunk, the A/B baseline)
CHUNK_ATTN_MODES = ("pallas", "xla", "naive")

_NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("scale", "block_s"))
def _chunk_attn_xla(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Array,
    v_scale: Array,
    *,
    start: Array,
    scale: float,
    block_s: int,
) -> Array:
    """XLA mirror of the chunk-attention kernel: identical blocked online-
    softmax int8 math (same per-block op sequence, same per-row q requant,
    same per-block prob re-quantization), an **unrolled** sweep over
    S-blocks in place of the Pallas grid sweep — a ``lax.scan`` here would
    break the bitwise contract (XLA's loop-body codegen fuses
    multiply-adds differently than the straight-line graph the
    interpreted kernel lowers to, a ~1-ulp divergence), and the block
    count is small by construction (prefix bucketing / the roofline
    block_s pick). Bitwise-identical to the kernel at the same
    ``block_s`` — skipped tail blocks keep the carry unchanged via a
    select, exactly as ``pl.when`` skips them, and the unconditional
    causal mask matches the kernel's diagonal-only branch because a mask
    that is all-true selects the unmasked values verbatim."""
    b, c, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh
    cg = c * group
    rb = b * kvh
    if s_len % block_s:
        raise ValueError(f"S={s_len} must tile by block_s={block_s}")
    n_steps = s_len // block_s

    # the kernel's own head fold (c-major row layout, pre-scaled): sharing
    # the helper keeps the mirror's layout glued to the kernel's — the
    # bitwise-parity contract depends on it
    qt = _fold_q(q, scale, kvh)
    kt = k_cache.reshape(rb, n_steps, block_s, d).transpose(1, 0, 2, 3)
    vt = v_cache.reshape(rb, n_steps, block_s, d).transpose(1, 0, 2, 3)
    kst = k_scale.astype(jnp.float32).reshape(rb, n_steps, block_s) \
        .transpose(1, 0, 2)
    vst = v_scale.astype(jnp.float32).reshape(rb, n_steps, block_s) \
        .transpose(1, 0, 2)
    starts = jnp.repeat(
        jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,)), kvh)
    st3 = starts[:, None, None]  # (rb, 1, 1)
    q_i8, q_s = _ref.requant_rows(qt, 127.0)  # (rb, cg, d) / (rb, cg, 1)

    m = jnp.full((rb, cg, 1), _NEG_INF, jnp.float32)
    l = jnp.zeros((rb, cg, 1), jnp.float32)
    acc = jnp.zeros((rb, cg, d), jnp.float32)
    for si in range(n_steps):
        kblk, ksblk, vblk, vsblk = kt[si], kst[si], vt[si], vst[si]
        logits_i = jax.lax.dot_general(
            q_i8, kblk, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )  # (rb, cg, bs)
        logits = logits_i.astype(jnp.float32) * (q_s * ksblk[:, None, :])
        cols = si * block_s + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 2)
        c_pos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) // group
        valid = cols <= st3 + c_pos
        logits = jnp.where(valid, logits, _NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(logits - m_new)
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv_f = jnp.where(valid, p * vsblk[:, None, :], 0.0)
        p_amax = jnp.max(jnp.abs(pv_f), axis=-1, keepdims=True)
        p_s = jnp.maximum(p_amax, 1e-12) / 127.0
        p_i8 = jnp.clip(jnp.round(pv_f / p_s), -127, 127).astype(jnp.int8)
        pv_i = jax.lax.dot_general(
            p_i8, vblk, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )  # (rb, cg, d)
        acc_new = acc * alpha + pv_i.astype(jnp.float32) * p_s
        # blocks wholly past the chunk frontier keep the carry unchanged —
        # the select form of the kernel's pl.when skip (bitwise no-op)
        live = si * block_s < st3 + c
        m = jnp.where(live, m_new, m)
        l = jnp.where(live, l_new, l)
        acc = jnp.where(live, acc_new, acc)
    out = (acc / jnp.maximum(l, 1e-20)).astype(q.dtype)
    return _unfold_o(out, b, c, h, d, kvh)


def _chunk_attn_naive(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Array,
    v_scale: Array,
    *,
    start: Array,
    scale: float,
) -> Array:
    """The pre-kernel attend_chunk math, kept as the A/B baseline: the
    whole S-length cache is dequantized to f32 and masked, the (B, C, KVH,
    G, S) logits/probs materialize — O(max_len) bytes per chunk regardless
    of the valid prefix (what `bench_prefill_chunk` charges it for)."""
    b, c, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh
    qf = q.astype(jnp.float32).reshape(b, c, kvh, group, d) * scale
    kf = k_cache.astype(jnp.float32) * k_scale[..., None].astype(jnp.float32)
    vf = v_cache.astype(jnp.float32) * v_scale[..., None].astype(jnp.float32)
    logits = jnp.einsum("bckgd,bksd->bckgs", qf, kf)
    cols = jnp.arange(s_len)
    rows = (jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,))[:, None]
            + jnp.arange(c)[None, :])  # (B, C) absolute query positions
    mask = cols[None, None, :] <= rows[:, :, None]  # (B, C, S)
    logits = jnp.where(mask[:, :, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bckgs,bksd->bckgd", probs, vf)
    return out.astype(q.dtype).reshape(b, c, h, d)


def chunk_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
    *,
    start: Array,
    scale: Optional[float] = None,
    block_tables: Optional[Array] = None,
    mode: Optional[str] = None,
    backend: str = "auto",
    interpret: bool = False,
    block_s: Optional[int] = None,
    prefix_bucket: Optional[int] = None,
) -> Array:
    """C-token chunked-prefill attention over the int8 KV cache.

    q:        [B, C, H, D] — the chunk's queries, at absolute positions
              ``start .. start+C-1``; their quantized KV must already be
              written into the cache (attend_chunk writes before calling)
    k_cache:  [B, KVH, S, D] int8 (attention-native layout)
    k_scale:  [B, KVH, S] per-token-per-head dequant scales (required)
    start:    scalar or (B,) int32 chunk start offset; the valid prefix
              after the chunk's write is ``start + C`` and queries are
              causal within the chunk (col <= start + row)

    **Paged mode** (``block_tables`` given): the cache operands are the
    BlockPool arrays — k/v [N_phys, KVH, page, D], scales [N_phys, KVH,
    page] — and ``block_tables`` [B, max_blocks] int32 maps logical
    blocks to physical pool blocks. The "pallas" mode resolves the
    indirection inside scalar-prefetched index maps
    (`chunk_attention_paged_pallas`) — only mapped blocks stream; the jnp
    modes gather the mapped blocks into a contiguous view first (trimmed
    to whole pages covering ``prefix_bucket`` when given).

    Mode resolution: explicit ``mode`` wins; otherwise ``REPRO_CHUNK_ATTN``
    picks one of ``CHUNK_ATTN_MODES`` ("pallas" default); anything else
    raises. "pallas" streams only the ``ceil((start+C)/block_s)`` S-blocks
    covering the valid prefix (scalar-prefetched clamp — the masked tail
    is neither fetched nor computed) and falls back to "xla" off-TPU
    unless ``interpret``. "xla" is the same blocked math jnp-lowered —
    bitwise-identical to the kernel at equal ``block_s`` — and applies
    **prefix bucketing**: with ``prefix_bucket`` (a static bound >=
    start+C, e.g. the engine's power-of-two rounding of the chunk
    frontier) only the first ``prefix_bucket`` cache positions are sliced
    and streamed, so the off-TPU cost is O(bucket), not O(max_len).
    Skipped/tail blocks are select-discarded, so bucketing never changes
    the result. "naive" is the original full-S dequantize-and-mask math.

    ``block_s`` defaults to `tuning.best_chunk_attn_block`'s roofline pick
    (page-divisor-restricted in paged mode). Returns [B, C, H, D] in q's
    dtype.
    """
    if mode is None:
        mode = os.environ.get("REPRO_CHUNK_ATTN", "pallas")
    if mode not in CHUNK_ATTN_MODES:
        raise ValueError(
            f"chunk_attention mode {mode!r} not in {CHUNK_ATTN_MODES} "
            "(check REPRO_CHUNK_ATTN)")
    if k_cache.dtype != jnp.int8 or k_scale is None or v_scale is None:
        missing = "k_scale" if k_scale is None else "v_scale"
        raise ValueError(
            "chunk_attention: an int8 KV cache with per-token scales is "
            f"required ({missing} is None or cache is not int8) — the "
            "chunked-prefill path always attends the quantized prefix")
    b, c, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    if prefix_bucket is not None and not isinstance(start, jax.core.Tracer):
        # a bucket below the chunk frontier would silently drop attended
        # prefix positions; catch it whenever ``start`` is concrete (the
        # engine passes a traced start but upholds the bound by
        # construction — see Engine._prefix_bucket)
        start_c = int(jnp.max(jnp.asarray(start)))
        if start_c + c > prefix_bucket:
            raise ValueError(
                f"chunk_attention: prefix_bucket={prefix_bucket} is below "
                f"the chunk frontier start+C={start_c + c} — the bucket "
                "must cover every position the chunk attends")

    if block_tables is not None:
        page = k_cache.shape[2]
        kvh = k_cache.shape[1]
        s_log = block_tables.shape[1] * page
        if mode == "pallas" and (_resolve(backend) == "pallas" or interpret):
            if block_s is None:
                block_s = tuning.best_chunk_attn_block(
                    b, kvh, h // kvh, c, s_log, d, page=page).block_s
            return chunk_attention_paged_pallas(
                q, k_cache, v_cache, k_scale, v_scale, block_tables,
                start=start, scale=scale, block_s=block_s,
                interpret=interpret)
        # jnp fallback: gather the mapped blocks into a contiguous view —
        # trimmed to the whole pages covering the prefix bucket, so the
        # gather itself is O(bucket) too
        nb = block_tables.shape[1]
        if prefix_bucket is not None and mode != "naive":
            nb = min(nb, -(-min(prefix_bucket, s_log) // page))
        bt = block_tables[:, :nb]

        def unpage(pool):
            g = pool[bt]
            if g.ndim == 5:
                return g.transpose(0, 2, 1, 3, 4).reshape(
                    g.shape[0], g.shape[2], -1, g.shape[4])
            return g.transpose(0, 2, 1, 3).reshape(
                g.shape[0], g.shape[2], -1)

        k_cache, v_cache = unpage(k_cache), unpage(v_cache)
        k_scale, v_scale = unpage(k_scale), unpage(v_scale)

    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh

    if mode == "pallas":
        if _resolve(backend) == "pallas" or interpret:
            if block_s is None:
                block_s = tuning.best_chunk_attn_block(
                    b, kvh, group, c, s_len, d).block_s
            return chunk_attention_pallas(
                q, k_cache, v_cache, k_scale, v_scale,
                start=start, scale=scale, block_s=block_s,
                interpret=interpret)
        mode = "xla"

    if mode == "xla":
        if prefix_bucket is not None and prefix_bucket < s_len:
            pb = max(int(prefix_bucket), 1)
            k_cache = k_cache[:, :, :pb]
            v_cache = v_cache[:, :, :pb]
            k_scale = k_scale[:, :, :pb]
            v_scale = v_scale[:, :, :pb]
            s_len = pb
        if block_s is None:
            block_s = tuning.best_chunk_attn_block(
                b, kvh, group, c, s_len, d).block_s
        return _chunk_attn_xla(q, k_cache, v_cache, k_scale, v_scale,
                               start=start, scale=scale, block_s=block_s)

    return _chunk_attn_naive(q, k_cache, v_cache, k_scale, v_scale,
                             start=start, scale=scale)

"""Chunked-prefill flash attention over the int8 KV cache (Pallas TPU).

The XLA-lowered chunked-prefill attention (`ops.chunk_attention` mode
"naive", the pre-kernel `attend_chunk` math) dequantizes and masks the
**entire max_len cache row per chunk**: O(S·C) work and HBM traffic for an
O(prefix·C) problem, plus (B, C, KVH, G, S) logits/probs materialized in
HBM. This kernel is the flash-attention form of the same math, the chunk
(Sq = C) generalization of `decode_attn.py`'s flash-decoding kernel
(Sq = 1): the int8 cache is streamed HBM→VMEM at most once per chunk,
S-blocks past the chunk frontier are neither fetched nor computed, and
nothing S-sized ever goes back to HBM.

* **Grid** is (B·KVH, S/block_s): one program row per KV head, a sequential
  sweep over S-blocks. All C·G query rows of a KV head (C chunk positions ×
  G = H/KVH grouped heads) are batched into a single (C·G, D) MXU tile —
  the whole chunk amortizes one cache pass, GQA without a repeated read.
* **In-VMEM dequant / fully-integer BMMs**: identical regime to the decode
  kernel — per-token k/v scales ride along as (1, block_s) f32 rows, q is
  re-quantized per row to int8 once per grid row (`requant_rows`, THE
  quantization core), QK and PV contract on the int8 MXU unit with the
  softmax probs folded with v_scale and re-quantized per row per block.
* **Online softmax**: running (max, sum, acc) for all C·G rows live in
  VMEM scratch across the S sweep — the FlashAttention-2 state machine at
  Sq = C.
* **Prefix-clamped block skipping**: the chunk start offset is a
  scalar-prefetch operand. The chunk occupies absolute positions
  ``start .. start+C-1`` and its KV is written before attending, so the
  valid prefix length is ``start + C``; S-blocks wholly past it are
  skipped both ways — the kv index maps clamp the block index to
  ``ceil((start+C)/block_s) - 1`` (consecutive identical indices → no
  tail DMA) and ``pl.when`` guards the body (no tail compute). NaN poison
  planted past the frontier provably never reaches the output
  (tests/test_chunk_attn_kernel.py).
* **Causal-within-chunk masking, diagonal blocks only**: query row (c, g)
  may attend columns <= start + c. S-blocks entirely before ``start`` are
  valid for every query row, so they take an unmasked fast path; the
  iota/compare/select masking runs only on the **diagonal** blocks that
  overlap ``[start, start+C)``, selected by ``pl.when``. The two branches
  are bitwise-identical where both are legal (a mask that is all-true
  selects the unmasked values verbatim), which is what makes the kernel
  bitwise-equal to the XLA mirror (`ops._chunk_attn_xla`) at equal tiling.

Contracts (shared by the contiguous and paged entry points)
-----------------------------------------------------------

* **Grid layout**: ``(B·KVH, S/block_s)`` — axis 0 "parallel", axis 1
  "arbitrary" (the S sweep carries the online-softmax state in order).
* **Scratch usage** (VMEM, live across one grid row's S sweep,
  re-initialized under ``pl.when(si == 0)``): ``m (C·G, 1) f32`` running
  max, ``l (C·G, 1) f32`` running sum, ``acc (C·G, D) f32`` running
  output, and the re-quantized query ``qi (C·G, D) int8`` / ``qs (C·G, 1)
  f32`` computed once per row (q is S-invariant).
* **Scalar-prefetch contract**: ``start_ref (B·KVH,) int32`` — the chunk's
  absolute start offset per grid row — drives the frontier clamp in the kv
  index maps and the ``pl.when`` guards. The paged entry point prefetches
  a second operand, ``bt_ref (B·max_blocks,) int32`` (flattened per-row
  block tables), and resolves ``(row, s_block) → physical pool block``
  inside the index maps exactly like ``decode_attention_paged_pallas`` —
  only mapped blocks stream, the scattered pool is never gathered.

Paged mode (`chunk_attention_paged_pallas`)
-------------------------------------------

The serving engine's `BlockPool` stores the cache as ``page``-token
physical blocks with per-slot block tables. The kernel body is identical;
only the kv/scale index maps change: clamped logical S-block ``sc`` maps
to ``bt[row, sc // per] * KVH + head`` with ``per = page // block_s``.
This is what lets ``Engine(prefill_chunk=..., kv_block_size=...)``
compose: a chunked prefill can attend its already-written paged prefix
without a contiguous copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params
from repro.kernels.ref import requant_rows

Array = jax.Array

_NEG_INF = -1e30

_CompilerParams = tpu_compiler_params()


def _chunk_attn_kernel(
    start_ref,  # scalar prefetch: (B*KVH,) int32 chunk start offsets
    q_ref,  # (1, C*G, D) f32 (pre-scaled by 1/sqrt(D))
    k_ref,  # (1, BS, D) int8
    ks_ref,  # (1, BS) f32 per-token K scales
    v_ref,  # (1, BS, D) int8
    vs_ref,  # (1, BS) f32 per-token V scales
    o_ref,  # (1, C*G, D) out dtype
    m_ref,  # VMEM (C*G, 1) f32 running max
    l_ref,  # VMEM (C*G, 1) f32 running sum
    acc_ref,  # VMEM (C*G, D) f32 running output
    qi_ref,  # VMEM (C*G, D) int8 re-quantized q (computed once per row)
    qs_ref,  # VMEM (C*G, 1) f32 q dequant scales
    *,
    block_s: int,
    s_steps: int,
    chunk: int,
    group: int,
):
    bh = pl.program_id(0)
    si = pl.program_id(1)
    start = start_ref[bh]
    end = start + chunk  # valid prefix length once the chunk is written

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        q_i8, q_s = requant_rows(q_ref[0], 127.0)
        qi_ref[...] = q_i8
        qs_ref[...] = q_s

    def _accumulate(masked: bool):
        """One S-block's online-softmax update. ``masked`` statically picks
        the diagonal (causal-within-chunk) branch; on blocks where the mask
        would be all-true the two branches are bitwise identical."""
        logits_i = jax.lax.dot_general(
            qi_ref[...], k_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (C*G, BS)
        logits = logits_i.astype(jnp.float32) * (qs_ref[...] * ks_ref[...])
        if masked:
            cols = si * block_s + jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, 1)
            # query-row chunk position: rows are laid out c-major (C, G)
            c_pos = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, 0) // group
            valid = cols <= start + c_pos
            logits = jnp.where(valid, logits, _NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        if masked:
            p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)

        pv_f = p * vs_ref[...]  # (C*G, BS)
        if masked:
            pv_f = jnp.where(valid, pv_f, 0.0)
        p_amax = jnp.max(jnp.abs(pv_f), axis=-1, keepdims=True)
        p_s = jnp.maximum(p_amax, 1e-12) / 127.0
        p_i8 = jnp.clip(jnp.round(pv_f / p_s), -127, 127).astype(jnp.int8)
        pv_i = jax.lax.dot_general(
            p_i8, v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (C*G, D)
        acc_ref[...] = acc_ref[...] * alpha + pv_i.astype(jnp.float32) * p_s
        m_ref[...] = m_new

    # blocks wholly past the frontier: no compute (and, via the clamped
    # index maps, no fetch). Of the computed blocks, only the *diagonal*
    # ones (overlapping [start, start+C)) pay the causal mask; prefix
    # blocks before ``start`` are valid for every query row.
    computed = si * block_s < end
    diagonal = (si + 1) * block_s > start

    @pl.when(computed & diagonal)
    def _diag_body():
        _accumulate(masked=True)

    @pl.when(computed & jnp.logical_not(diagonal))
    def _prefix_body():
        _accumulate(masked=False)

    @pl.when(si == s_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _chunk_scratch(cg: int, d: int):
    return [
        pltpu.VMEM((cg, 1), jnp.float32),
        pltpu.VMEM((cg, 1), jnp.float32),
        pltpu.VMEM((cg, d), jnp.float32),
        pltpu.VMEM((cg, d), jnp.int8),
        pltpu.VMEM((cg, 1), jnp.float32),
    ]


def _fold_q(q: Array, scale: float, kvh: int) -> Array:
    """(B, C, H, D) -> (B*KVH, C*G, D), pre-scaled, c-major row layout."""
    b, c, h, d = q.shape
    group = h // kvh
    qt = (q.astype(jnp.float32) * scale).reshape(b, c, kvh, group, d)
    return qt.transpose(0, 2, 1, 3, 4).reshape(b * kvh, c * group, d)


def _unfold_o(out: Array, b: int, c: int, h: int, d: int, kvh: int) -> Array:
    group = h // kvh
    return out.reshape(b, kvh, c, group, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, c, h, d)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret"),
)
def chunk_attention_pallas(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_scale: Array,
    v_scale: Array,
    *,
    start: Array,
    scale: float,
    block_s: int = 256,
    interpret: bool = False,
) -> Array:
    """C-token chunk attention over the int8 cache, one clamped HBM pass.

    q:        (B, C, H, D) float — the chunk's queries, at absolute
              positions ``start .. start+C-1`` (KV already written there)
    k_cache:  (B, KVH, S, D) int8 (attention-native layout)
    k_scale:  (B, KVH, S) f32 per-token-per-head dequant scales
    start:    scalar or (B,) int32 chunk start offset
    block_s:  S-tile length; must divide S (use
              `tuning.best_chunk_attn_block` for the roofline pick)

    Returns (B, C, H, D) in q's dtype. Bitwise-identical to
    `ops.chunk_attention(mode="xla")` at the same block_s (pinned by
    tests/test_chunk_attn_kernel.py).
    """
    b, c, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh
    if s_len % block_s:
        raise ValueError(f"S={s_len} must tile by block_s={block_s}")
    s_steps = s_len // block_s

    qt = _fold_q(q, scale, kvh)
    kt = k_cache.reshape(b * kvh, s_len, d)
    vt = v_cache.reshape(b * kvh, s_len, d)
    kst = k_scale.astype(jnp.float32).reshape(b * kvh, s_len)
    vst = v_scale.astype(jnp.float32).reshape(b * kvh, s_len)
    starts = jnp.repeat(
        jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,)), kvh)

    def _clamp(si, st_ref, bh):
        # last block covering the chunk frontier start + C; revisiting it
        # on tail iterations keeps the mapped index constant -> no tail DMA
        n_blocks = jax.lax.div(st_ref[bh] + c + block_s - 1, block_s)
        return jnp.minimum(si, jnp.maximum(n_blocks - 1, 0))

    def q_map(bh, si, st_ref):
        return (bh, 0, 0)

    def kv_map(bh, si, st_ref):
        return (bh, _clamp(si, st_ref, bh), 0)

    def sc_map(bh, si, st_ref):
        return (bh, _clamp(si, st_ref, bh))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * kvh, s_steps),
        in_specs=[
            pl.BlockSpec((1, c * group, d), q_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
        ],
        out_specs=pl.BlockSpec((1, c * group, d), q_map),
        scratch_shapes=_chunk_scratch(c * group, d),
    )
    out = pl.pallas_call(
        functools.partial(
            _chunk_attn_kernel, block_s=block_s, s_steps=s_steps,
            chunk=c, group=group,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, c * group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts, qt, kt, kst, vt, vst)
    return _unfold_o(out, b, c, h, d, kvh)


def _paged_chunk_attn_kernel(start_ref, bt_ref, *refs, block_s, s_steps,
                             chunk, group):
    """The contiguous kernel body verbatim: the block table is consumed
    entirely by the index maps (DMA descriptor generation on the scalar
    core); the compute loop never sees the indirection."""
    del bt_ref
    _chunk_attn_kernel(start_ref, *refs, block_s=block_s, s_steps=s_steps,
                       chunk=chunk, group=group)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret"),
)
def chunk_attention_paged_pallas(
    q: Array,
    k_pool: Array,
    v_pool: Array,
    k_scale: Array,
    v_scale: Array,
    block_tables: Array,
    *,
    start: Array,
    scale: float,
    block_s: int | None = None,
    interpret: bool = False,
) -> Array:
    """C-token chunk attention over the *paged* int8 pool, one clamped pass.

    q:            (B, C, H, D) float
    k_pool:       (N_phys, KVH, page, D) int8 — BlockPool device arrays
                  (one layer's slice); row 0 is the TRASH block
    k_scale:      (N_phys, KVH, page) f32 per-token dequant scales
    block_tables: (B, max_blocks) int32 logical→physical block map; every
                  block covering ``start + C`` positions must be mapped
                  (the engine pre-maps the chunk's blocks before the step)
    start:        scalar or (B,) int32 chunk start offset
    block_s:      S-tile length; must divide ``page`` (default: ``page``)

    Returns (B, C, H, D) in q's dtype — bitwise identical to
    `chunk_attention_pallas` over the equivalent contiguous cache **at the
    same block_s** (pinned by tests/test_chunk_attn_kernel.py).
    """
    b, c, h, d = q.shape
    n_phys, kvh, page = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    group = h // kvh
    nb = block_tables.shape[1]
    s_len = nb * page
    if block_s is None:
        block_s = page
    if page % block_s:
        raise ValueError(f"page={page} must tile by block_s={block_s}")
    per = page // block_s
    s_steps = s_len // block_s

    qt = _fold_q(q, scale, kvh)
    kt = k_pool.reshape(n_phys * kvh, page, d)
    vt = v_pool.reshape(n_phys * kvh, page, d)
    kst = k_scale.astype(jnp.float32).reshape(n_phys * kvh, page)
    vst = v_scale.astype(jnp.float32).reshape(n_phys * kvh, page)
    starts = jnp.repeat(
        jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,)), kvh)
    bt = block_tables.astype(jnp.int32).reshape(-1)  # (B * max_blocks,)

    def _clamp(si, st_ref, bh):
        n_blocks = jax.lax.div(st_ref[bh] + c + block_s - 1, block_s)
        return jnp.minimum(si, jnp.maximum(n_blocks - 1, 0))

    def _resolve(bh, si, st_ref, bt_ref):
        """(grid row, clamped s-block) -> (physical pool row, sub-block)."""
        sc = _clamp(si, st_ref, bh)
        bi = jax.lax.div(bh, kvh)
        hi = jax.lax.rem(bh, kvh)
        phys = bt_ref[bi * nb + jax.lax.div(sc, per)]
        return phys * kvh + hi, jax.lax.rem(sc, per)

    def q_map(bh, si, st_ref, bt_ref):
        return (bh, 0, 0)

    def kv_map(bh, si, st_ref, bt_ref):
        row, j = _resolve(bh, si, st_ref, bt_ref)
        return (row, j, 0)

    def sc_map(bh, si, st_ref, bt_ref):
        row, j = _resolve(bh, si, st_ref, bt_ref)
        return (row, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * kvh, s_steps),
        in_specs=[
            pl.BlockSpec((1, c * group, d), q_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
            pl.BlockSpec((1, block_s, d), kv_map),
            pl.BlockSpec((1, block_s), sc_map),
        ],
        out_specs=pl.BlockSpec((1, c * group, d), q_map),
        scratch_shapes=_chunk_scratch(c * group, d),
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_chunk_attn_kernel, block_s=block_s, s_steps=s_steps,
            chunk=c, group=group,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, c * group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts, bt, qt, kt, kst, vt, vst)
    return _unfold_o(out, b, c, h, d, kvh)

"""ABQKernel for TPU: arbitrary-bit quantized GEMM as a Pallas kernel.

TPU-native reconstruction of the paper's BTC engine (DESIGN.md §2):

  Y[M, N] = x_scale ⊙ w_scale ⊙ ( Σ_s 2^s (X_q @ Wˢ) − w_zp ⊙ rowsum(X_q) )

* ``X_q``  int8 [M, K]   — per-token symmetric activation container (any p ≤ 8)
* ``Wˢ``   bit-planes packed uint32 [P, K/32, N] — only q/16 of the bf16 bytes
  cross HBM→VMEM, which is where the decode-GEMV win lives on TPU.
* unpack (VPU shift/mask) happens on the VMEM tile inside the K-loop; each
  plane feeds a 128-aligned int8×int8→int32 MXU matmul; the ``2^s`` plane
  weights and the affine dequant run in the epilogue (the paper's
  Bit Reduction step).

Grid: (M/BM, N/BN, K/BK), K innermost ("arbitrary" semantics) so the fp32
accumulator lives in VMEM scratch across the K sweep. Pallas double-buffers
the HBM→VMEM streams automatically — the analogue of the paper's cp.async
pipeline (Appendix D, Computational Pipeline Optimization).

Block sizes are the paper's Auto Kernel Search knob: callers that do not
pin them get per-shape tiles from `repro.kernels.tuning.best_blocks` (via
the `ops.abq_matmul` wrapper) — decode GEMV shapes select small-M
weight-stationary tiles (BM <= 32), prefill keeps MXU-saturating 128-class
tiles. This kernel consumes a pre-quantized int8 activation; the decode
fast-path normally runs its fused sibling `abq_fused.abq_linear_fused_pallas`
instead (ReQuant in the kernel prologue, no HBM round-trip of the int8
container — A/B toggle ``REPRO_ABQ_FUSED``, see `ops.abq_linear`). This
unfused kernel remains the baseline half of that A/B and the path for
per-group (g128) weights and VMEM-busting contraction lengths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params

Array = jax.Array

WORD = 32

_CompilerParams = tpu_compiler_params()


def _unpack_words(words: Array, bk: int, bn: int) -> Array:
    """uint32 (BK/32, BN) -> int8 {0,1} (BK, BN).

    VPU shift+mask; the reshape interleaves word-bits back into contraction
    order (bit b of word w is contraction index 32*w + b).
    """
    shifts = jnp.arange(WORD, dtype=jnp.uint32)[None, :, None]
    bits = (words[:, None, :] >> shifts) & jnp.uint32(1)
    return bits.reshape(bk, bn).astype(jnp.int8)


def _abq_kernel(
    x_ref,  # int8 (BM, BK)
    planes_ref,  # uint32 (P, BK/32, BN)
    xs_ref,  # f32 (BM, 1)
    ws_ref,  # f32 (1, BN)
    zp_ref,  # f32 (1, BN)
    o_ref,  # (BM, BN) out dtype
    acc_ref,  # f32 VMEM scratch (BM, BN)
    rs_ref,  # f32 VMEM scratch (BM, 1)
    *,
    n_planes: int,
    k_steps: int,
    out_dtype,
):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rs_ref[...] = jnp.zeros_like(rs_ref)

    x = x_ref[...]
    bm, bk = x.shape
    bn = o_ref.shape[-1]

    acc = jnp.zeros((bm, bn), jnp.int32)
    for s in range(n_planes):  # static unroll over planes (P <= 8, usually 2-4)
        w_bits = _unpack_words(planes_ref[s], bk, bn)
        part = jax.lax.dot_general(
            x,
            w_bits,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc += part << s
    acc_ref[...] += acc.astype(jnp.float32)
    rs_ref[...] += jnp.sum(
        x.astype(jnp.int32), axis=1, keepdims=True
    ).astype(jnp.float32)

    @pl.when(kstep == k_steps - 1)
    def _epilogue():
        deq = xs_ref[...] * (
            ws_ref[...] * (acc_ref[...] - zp_ref[...] * rs_ref[...])
        )
        o_ref[...] = deq.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def abq_matmul_pallas(
    x_q: Array,
    x_scale: Array,
    planes: Array,
    w_scale: Array,
    w_zp: Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> Array:
    """Launch the ABQ GEMM. Shapes as in `repro.kernels.ref.abq_matmul_ref`
    with K already padded to a multiple of 32 (`bitplane.pack_bitplanes` pads;
    the ops wrapper zero-pads the activation rows to match).

    M is padded to block_m inside; N and K must tile by (block_n, block_k) —
    production model dims are 128-aligned, the ops wrapper pads otherwise.
    """
    m, kk = x_q.shape
    n_planes, kw, n = planes.shape
    if kw * WORD != kk:
        raise ValueError(f"planes imply K={kw * WORD}, activations have K={kk}")
    block_k = min(block_k, kk)
    block_n = min(block_n, n)
    if kk % block_k != 0 or block_k % WORD != 0:
        raise ValueError(f"K={kk} must tile by block_k={block_k} (mult of 32)")
    if n % block_n != 0:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    pm = (m + block_m - 1) // block_m * block_m
    if pm != m:
        x_q = jnp.pad(x_q, ((0, pm - m), (0, 0)))
        x_scale = jnp.pad(x_scale, ((0, pm - m), (0, 0)))
    k_steps = kk // block_k
    grid = (pm // block_m, n // block_n, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _abq_kernel,
            n_planes=n_planes,
            k_steps=k_steps,
            out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kq: (i, kq)),
            pl.BlockSpec(
                (n_planes, block_k // WORD, block_n),
                lambda i, j, kq: (0, kq, j),
            ),
            pl.BlockSpec((block_m, 1), lambda i, j, kq: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kq: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kq: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, planes, x_scale, w_scale, w_zp)
    return out[:m]

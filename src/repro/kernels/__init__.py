"""Pallas TPU kernels (+ XLA-path twins and pure-jnp oracles).

Kernels:
  abq_matmul        — arbitrary-bit quantized GEMM (the paper's ABQKernel)
  abq_fused         — ReQuant+GEMM fusion (the decode linear fast-path)
  act_quant         — fused per-token ReQuant
  flash_attention   — causal GQA flash attention for prefill
  decode_attn       — flash-decoding over the int8 KV cache (decode)
"""

from repro.kernels.ops import (
    abq_linear,
    abq_matmul,
    act_quant,
    decode_attention,
    default_backend,
    flash_attention,
)

__all__ = [
    "abq_linear",
    "abq_matmul",
    "act_quant",
    "decode_attention",
    "default_backend",
    "flash_attention",
]

"""Fused ReQuant + arbitrary-bit GEMM Pallas kernel (the decode fast-path).

The unfused serving path launches two kernels per linear:

    bf16 x --[act_quant]--> int8 q, f32 s --(HBM round-trip)--> [abq_matmul]

which writes the int8 activation + scales to HBM only for the very next
kernel to read them back. The paper fuses online activation quantization
into the adjacent GEMM (§3.4 "Engine Implementation", Fig. 4b); this kernel
is the TPU form of that fusion:

* the x tile streams HBM→VMEM **once**, in bf16;
* the kernel prologue computes per-token absmax → scale → round → clip on
  the VPU — bit-identical math to `act_quant_pallas` / `act_quant_ref`;
* the int8 container feeds the bit-plane MXU matmuls directly from VMEM —
  the quantized activation never touches HBM;
* the epilogue applies the combined activation/weight dequant.

Grid is (M/BM, N/BN) with the **full contraction length per tile** (the
per-token scale needs the whole row, and decode rows are small): a
weight-stationary GEMV schedule. The ops-layer dispatcher
(`repro.kernels.ops.abq_linear`) falls back to the unfused two-kernel path
when the full-K tile would bust the VMEM budget (`fits_vmem`) or for
per-group (g128) weights.

`debug_return_quant=True` additionally writes the int8 container + scales
to HBM so tests can assert bitwise identity with the unfused path — never
used in the serving path (it would re-create the traffic the fusion
deletes).

Contracts
---------

* **Grid layout**: ``(M/BM, N/BN)``, both axes "parallel" — every
  (M-tile, N-tile) program is independent because each one re-runs the
  ReQuant prologue on its own x tile (no cross-tile state). That
  redundancy is the current cost of parallelism; hoisting (q, scale) into
  VMEM scratch under ``pl.when(j == 0)`` would require "arbitrary"
  semantics on the N axis (ROADMAP: prologue hoisting).
* **Scratch usage**: none — the int8 container ``q``, its scales, and the
  int32 accumulator live as kernel-local values (VMEM-backed registers),
  sized by the BlockSpec tiles: ``(BM, K)`` activation tile, P×
  ``(K/32, BN)`` packed plane tiles, ``(BM, BN)`` accumulator. `fits_vmem`
  is the dispatcher's admission check: a full-K fused tile that would
  bust the VMEM budget falls back to the unfused two-kernel path.
* **Scalar-prefetch**: none needed — all tile addressing is affine in the
  grid indices (contrast `decode_attn.py`, where valid lengths and block
  tables must be prefetched for the index maps).
* **The one-transfer-per-step invariant** (serving): this kernel is why
  the engine's decode step makes no intermediate HBM round-trips on the
  linear path — activations stream in bf16, quantize in the prologue, and
  contract from VMEM; combined with the scan-accumulated token block
  (`serving/engine.py`), a whole engine step touches the host exactly
  once, for the stacked tokens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.abq_matmul import WORD, _CompilerParams, _unpack_words
from repro.kernels.ref import requant_rows

Array = jax.Array


def _fused_kernel(
    x_ref,  # bf16/f32 (BM, K)
    planes_ref,  # uint32 (P, K/32, BN)
    ws_ref,  # f32 (1, BN)
    zp_ref,  # f32 (1, BN)
    o_ref,  # (BM, BN) out dtype
    *debug_refs,  # optionally (q_ref (BM, K) int8, s_ref (BM, 1) f32)
    n_planes: int,
    qmax: float,
    out_dtype,
):
    bm, kk = x_ref.shape
    bn = o_ref.shape[-1]

    # ReQuant prologue: per-token symmetric int8 container, VPU only —
    # the same `requant_rows` the standalone quantizer runs, so the
    # container is bitwise identical to the unfused path. Zero-padded K
    # columns contribute |0| to the absmax and quantize to 0.
    q, scale = requant_rows(x_ref[...], qmax)

    acc = jnp.zeros((bm, bn), jnp.int32)
    for s in range(n_planes):  # static unroll over planes (P <= 8)
        w_bits = _unpack_words(planes_ref[s], kk, bn)
        part = jax.lax.dot_general(
            q,
            w_bits,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc += part << s
    rowsum = jnp.sum(q.astype(jnp.int32), axis=1, keepdims=True)
    deq = scale * (
        ws_ref[...] * (acc.astype(jnp.float32)
                       - zp_ref[...] * rowsum.astype(jnp.float32))
    )
    o_ref[...] = deq.astype(out_dtype)
    if debug_refs:  # tests only: emit the container the GEMM consumed
        q_ref, s_ref = debug_refs
        q_ref[...] = q
        s_ref[...] = scale


def fits_vmem(m_block: int, k: int, n_block: int, n_planes: int,
              budget: int) -> bool:
    """Conservative VMEM estimate for one fused tile.

    f32 x copy + int8 container + packed planes + one unpacked plane +
    int32/f32 accumulators; doubled for Pallas' automatic double-buffering
    of the streamed inputs.
    """
    x_bytes = (4 + 1 + 2) * m_block * k
    plane_bytes = 4 * n_planes * (k // WORD) * n_block + k * n_block
    acc_bytes = (4 + 4) * m_block * n_block
    return 2 * (x_bytes + plane_bytes) + acc_bytes <= budget


@functools.partial(
    jax.jit,
    static_argnames=("qmax", "block_m", "block_n", "out_dtype",
                     "debug_return_quant", "interpret"),
)
def abq_linear_fused_pallas(
    x: Array,
    planes: Array,
    w_scale: Array,
    w_zp: Array,
    *,
    qmax: float = 127.0,
    block_m: int = 32,
    block_n: int = 128,
    out_dtype=jnp.bfloat16,
    debug_return_quant: bool = False,
    interpret: bool = False,
):
    """bf16/f32 x [M, K] × packed weight -> [M, N] without an HBM round-trip
    of the quantized activation.

    K must equal the planes' padded contraction length (callers zero-pad —
    `ops.abq_linear` does); N must tile by ``block_n`` (after clamping).
    Returns the output, or (out, q, scales) when ``debug_return_quant``.
    """
    m, kk = x.shape
    n_planes, kw, n = planes.shape
    if kw * WORD != kk:
        raise ValueError(f"planes imply K={kw * WORD}, activations have K={kk}")
    block_n = min(block_n, n)
    if n % block_n != 0:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    pm = (m + block_m - 1) // block_m * block_m
    if pm != m:
        x = jnp.pad(x, ((0, pm - m), (0, 0)))
    grid = (pm // block_m, n // block_n)

    in_specs = [
        pl.BlockSpec((block_m, kk), lambda i, j: (i, 0)),
        pl.BlockSpec((n_planes, kw, block_n), lambda i, j: (0, 0, j)),
        pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
    ]
    if debug_return_quant:
        out, q, s = pl.pallas_call(
            functools.partial(
                _fused_kernel, n_planes=n_planes, qmax=qmax,
                out_dtype=out_dtype,
            ),
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
                # every j block writes the same values: harmless, debug-only
                pl.BlockSpec((block_m, kk), lambda i, j: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((pm, n), out_dtype),
                jax.ShapeDtypeStruct((pm, kk), jnp.int8),
                jax.ShapeDtypeStruct((pm, 1), jnp.float32),
            ],
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(x, planes, w_scale, w_zp)
        return out[:m], q[:m], s[:m]

    out = pl.pallas_call(
        functools.partial(
            _fused_kernel, n_planes=n_planes, qmax=qmax, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, n), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, planes, w_scale, w_zp)
    return out[:m]

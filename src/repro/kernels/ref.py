"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: small, obvious implementations with
no tiling, used by the test suite (`tests/test_kernel_*.py`) to check the
Pallas kernels (run in interpret mode on CPU) over shape/dtype sweeps.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitplane

Array = jax.Array


def abq_matmul_ref(
    x_q: Array,
    x_scale: Array,
    planes: Array,
    w_scale: Array,
    w_zp: Array,
    k: int,
    out_dtype=jnp.bfloat16,
) -> Array:
    """Arbitrary-bit integer GEMM, weight-side bit-plane decomposition.

    x_q:     int8 [M, K] symmetric per-token container values.
    x_scale: f32 [M, 1] per-token activation scales.
    planes:  uint32 [P, Kp/32, N] packed weight bit-planes.
    w_scale: f32 [1, N] per-out-channel weight scale.
    w_zp:    f32 [1, N] per-out-channel zero point (unsigned-grid).
    k:       unpadded contraction length.

    Y = x_scale * w_scale * (sum_s 2^s (x_q @ W^s) - zp * rowsum(x_q))
    """
    n_planes = planes.shape[0]
    w_bits = bitplane.unpack_bitplanes(planes, k, dtype=jnp.int8)  # [P, K, N]
    xi = x_q.astype(jnp.int32)
    acc = jnp.zeros((x_q.shape[0], planes.shape[-1]), jnp.int32)
    for s in range(n_planes):
        part = jax.lax.dot_general(
            xi,
            w_bits[s].astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << s)
    rowsum = jnp.sum(xi, axis=1, keepdims=True)
    out = x_scale * (w_scale * (acc.astype(jnp.float32) - w_zp * rowsum))
    return out.astype(out_dtype)


def abq_matmul_grouped_ref(
    x_q: Array,
    x_scale: Array,
    planes: Array,
    w_scale: Array,
    w_zp: Array,
    k: int,
    group_size: int,
    out_dtype=jnp.bfloat16,
) -> Array:
    """Per-group (g128) variant: scale/zp are (K/gs, 1, N)."""
    n_groups = k // group_size
    w_bits = bitplane.unpack_bitplanes(planes, k, dtype=jnp.int8)
    xi = x_q.astype(jnp.int32)
    m = x_q.shape[0]
    n = planes.shape[-1]
    out = jnp.zeros((m, n), jnp.float32)
    for g in range(n_groups):
        sl = slice(g * group_size, (g + 1) * group_size)
        acc = jnp.zeros((m, n), jnp.int32)
        for s in range(planes.shape[0]):
            part = jax.lax.dot_general(
                xi[:, sl],
                w_bits[s][sl].astype(jnp.int32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            acc = acc + (part << s)
        rs = jnp.sum(xi[:, sl], axis=1, keepdims=True)
        out = out + w_scale[g] * (acc.astype(jnp.float32) - w_zp[g] * rs)
    return (x_scale * out).astype(out_dtype)


def requant_rows(x: Array, qmax: float) -> tuple[Array, Array]:
    """THE per-token symmetric quantization core: absmax → scale (1e-8
    floor) → round → clip. Every path — the act_quant Pallas kernel, the
    fused ReQuant+GEMM kernel prologue, and the XLA mirrors — calls this
    one function; its bitwise behavior is a tested cross-path invariant
    (tests/test_fused_decode.py), so change it here or nowhere.

    Returns (int8 values [..., D], f32 scales [..., 1]).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def act_quant_ref(x: Array, qmax: float = 127.0) -> tuple[Array, Array]:
    """Per-token symmetric quantization: returns (int8 values, f32 scales)."""
    return requant_rows(x, qmax)


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> Array:
    """Reference attention. q: [B, Sq, H, D]; k/v: [B, Skv, KVH, D].

    GQA: H % KVH == 0, query head h uses kv head h // (H // KVH).
    ``q_offset``: absolute position of q[0] (for decode: Skv - Sq).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    if scale is None:
        scale = 1.0 / (d**0.5)
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(skv)[None, :]
        mask = qi >= ki
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)

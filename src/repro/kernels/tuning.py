"""Auto Kernel Search for the ABQ GEMM (the paper's Appendix D, TPU form).

On GPU the paper benchmarks candidate (BM, BN, BK, warp layout) tiles and
keeps the fastest. Without wall-clock on this container, the TPU version
ranks candidates with the v5e roofline cost model (HBM stream vs MXU time,
double-buffered) under the VMEM budget; on real TPU the same search loop
plugs a wall-clock ``measure`` callable in place of the model.

Two regimes fall out of the model naturally:

* prefill / training GEMM (M large): weight streaming amortizes over many
  M passes, big (128/256) M tiles win;
* decode GEMV (M = batch, ~1-32): the kernel pads M up to ``block_m``, so
  every padded row is wasted MXU work *and* wasted activation bytes — the
  model charges both (``m_pad``), which is what drives the search to the
  small weight-stationary tiles (BM <= 32) the decode fast-path needs.

``best_blocks`` is the dispatch entry: a per-(M, K, N, w_bits) cached search
restricted to tile shapes the Pallas kernel accepts (BK | K, BK % 32 == 0,
BN | N), used by `repro.kernels.ops.abq_matmul` / `abq_linear` whenever the
caller does not pin blocks explicitly. `benchmarks/bench_kernel_ablation.py`
(Table 4 analogue) uses the raw ``auto_tune`` search.

``best_decode_attn_block`` is the same idea for the decode-attention kernel
(`kernels/decode_attn.py`): a per-(B, KVH, G, S, D) cached block-S pick
ranked by the cache-bytes roofline (`decode_attn_cost`), balancing tail-byte
waste at short valid prefixes against per-grid-step overhead at long S.
``best_chunk_attn_block`` extends it to the chunked-prefill kernel
(`kernels/chunk_attn.py`): same search, cost charged over representative
chunk offsets, candidates restricted to page divisors in paged mode.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Optional

HBM_BW = 819e9
INT8_PEAK = 394e12
VMEM_BYTES = 128 * 2**20

_BM_CANDIDATES = (8, 16, 32, 64, 128, 256)
_BN_CANDIDATES = (128, 256, 512)
_BK_CANDIDATES = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class KernelCandidate:
    block_m: int
    block_n: int
    block_k: int
    t_us: float
    hbm_bytes: float
    vmem_bytes: float


def model_cost(m: int, k: int, n: int, *, w_bits: int, packed: bool = True,
               overlap: bool = True, bm: int = 128, bn: int = 128,
               bk: int = 512) -> dict:
    """HBM traffic + MXU time for one tiled bit-plane GEMM invocation.

    M is padded up to a multiple of ``bm`` by the kernel, so both the MXU
    op count and the streamed activation bytes are charged at the padded
    ``m_pad`` — oversizing BM for a decode GEMV is visibly expensive here.
    """
    m_eff = max(m, 8)
    m_pad = (m_eff + bm - 1) // bm * bm
    planes = w_bits if packed else 8
    passes = max(m_pad // bm, 1)  # weight tiles re-streamed per M pass
    w_bytes = passes * (planes * k * n / 8)
    a_bytes = (n // max(bn, 1)) * (m_pad * k)  # act tile re-read per N block
    o_bytes = 2 * m_pad * n
    total_bytes = w_bytes + a_bytes + o_bytes
    ops = 2.0 * m_pad * k * n * planes
    t_mem = total_bytes / HBM_BW
    t_cmp = ops / INT8_PEAK
    t = max(t_mem, t_cmp) if overlap else t_mem + t_cmp
    vmem = bm * bk + bk * bn + 4 * bm * bn + planes * bk * bn / 8
    return {"t_us": t * 1e6, "bytes": total_bytes, "vmem": vmem}


def auto_tune(
    m: int,
    k: int,
    n: int,
    *,
    w_bits: int,
    measure: Optional[Callable[[int, int, int], float]] = None,
    vmem_budget: int = VMEM_BYTES // 4,  # double-buffering headroom
    require_divisible: bool = False,
) -> KernelCandidate:
    """Pick (BM, BN, BK) minimizing modeled (or measured) time.

    ``require_divisible`` restricts the search to tiles `abq_matmul_pallas`
    accepts verbatim: BK divides K (and is a multiple of 32), BN divides N.
    """
    best: Optional[KernelCandidate] = None
    bn_cands = _BN_CANDIDATES if not require_divisible else \
        tuple(sorted({min(c, n) for c in _BN_CANDIDATES} | {n}))
    bk_cands = _BK_CANDIDATES if not require_divisible else \
        tuple(sorted({min(c, k) for c in _BK_CANDIDATES} | {k}))
    for bm, bn, bk in itertools.product(_BM_CANDIDATES, bn_cands, bk_cands):
        if bk > k or bn > n or bk % 32:
            continue
        if require_divisible and (k % bk or n % bn):
            continue
        r = model_cost(m, k, n, w_bits=w_bits, bm=bm, bn=bn, bk=bk)
        if r["vmem"] > vmem_budget:
            continue
        t = measure(bm, bn, bk) if measure is not None else r["t_us"]
        cand = KernelCandidate(bm, bn, bk, t, r["bytes"], r["vmem"])
        if best is None or cand.t_us < best.t_us:
            best = cand
    if best is None:
        raise ValueError(f"no feasible block config for ({m},{k},{n})")
    return best


# ---------------------------------------------------------------------------
# decode-attention shape class (block-S selection for kernels/decode_attn.py)
# ---------------------------------------------------------------------------

_BS_CANDIDATES = (128, 256, 512, 1024, 2048)
# fixed per-grid-step cost (DMA issue; the grid itself is pipelined so the
# marginal cost is small); penalizes tiny S-blocks at long S the same way
# m_pad penalizes oversized BM in the GEMM search
GRID_STEP_US = 0.02


@dataclasses.dataclass(frozen=True)
class DecodeAttnCandidate:
    block_s: int
    t_us: float
    cache_bytes: float
    vmem_bytes: float


def decode_attn_cost(batch: int, kvh: int, group: int, s: int, d: int, *,
                     block_s: int, valid_len: Optional[int] = None) -> dict:
    """Roofline cost of one decode-attention step at one S-tile size.

    Mirrors `model_cost`'s padding logic on the sequence axis: the kernel
    fetches whole S-blocks, so a ``valid_len`` prefix costs
    ``ceil(valid_len / block_s) * block_s`` positions of cache stream —
    oversizing block_s wastes tail bytes exactly like oversizing BM wastes
    padded GEMV rows. Every grid step (skipped or not) pays GRID_STEP_US,
    which is what keeps the search off degenerate 1-row tiles.
    """
    valid_len = s if valid_len is None else valid_len
    rows = batch * kvh
    fetched = (max(valid_len, 1) + block_s - 1) // block_s * block_s
    fetched = min(fetched, s)
    pos_bytes = 2 * d + 2 * 4  # int8 k + int8 v + f32 k/v scales per position
    cache_bytes = rows * fetched * pos_bytes
    qo_bytes = rows * group * d * (4 + 4)  # q read + out write, f32
    total_bytes = cache_bytes + qo_bytes
    ops = 2.0 * rows * fetched * group * d * 2  # QK + PV int8 BMMs
    t_mem = total_bytes / HBM_BW
    t_cmp = ops / INT8_PEAK
    t_grid = rows * (s // block_s) * GRID_STEP_US * 1e-6
    t = max(t_mem, t_cmp) + t_grid
    # double-buffered k/v tiles + scale rows, plus the resident q/acc state
    vmem = 2 * (2 * block_s * d + 2 * 4 * block_s) + group * d * (4 + 4 + 4)
    return {"t_us": t * 1e6, "cache_bytes": cache_bytes, "vmem": vmem}


def _search_decode_attn_block(
    batch: int, kvh: int, group: int, s: int, d: int,
    measure: Optional[Callable[[int], float]] = None,
    cands: Optional[tuple] = None,
) -> DecodeAttnCandidate:
    """block_s search shared by the modeled (cached) and measured paths.

    Candidates are restricted to tiles the kernel accepts (block_s | S) and
    that fit the VMEM budget; the roofline cost is averaged over
    representative valid-prefix lengths (S/8, S/2, S) so the modeled pick
    balances tail-byte waste at short prefixes (favors small blocks)
    against grid-step overhead at long S (favors large blocks) — the
    cache-bytes analogue of the GEMM search's decode-vs-prefill regimes.
    A ``measure`` callable (block_s -> time, any consistent unit) replaces
    the modeled ranking, exactly like the GEMM `auto_tune`'s measure hook;
    legality filtering stays model-side either way. ``cands`` overrides
    the candidate set (the paged search passes page divisors); ranking
    and the VMEM admission rule are shared regardless.
    """
    if cands is None:
        cands = sorted({c for c in _BS_CANDIDATES
                        if c <= s and s % c == 0} | {s})
    best: Optional[DecodeAttnCandidate] = None
    lens = sorted({max(s // 8, 1), max(s // 2, 1), s})
    for bs in cands:
        rs = [decode_attn_cost(batch, kvh, group, s, d, block_s=bs,
                               valid_len=ln) for ln in lens]
        if rs[0]["vmem"] > VMEM_BYTES // 4:
            continue
        t = measure(bs) if measure is not None \
            else sum(r["t_us"] for r in rs) / len(rs)
        # lens is sorted with s last: rs[-1] is the full-length cost
        cand = DecodeAttnCandidate(bs, t, rs[-1]["cache_bytes"],
                                   rs[0]["vmem"])
        if best is None or cand.t_us < best.t_us:
            best = cand
    if best is None:
        raise ValueError(
            f"no feasible decode-attn block for (B={batch},KVH={kvh},"
            f"G={group},S={s},D={d})")
    return best


_best_decode_attn_block_modeled = functools.lru_cache(maxsize=4096)(
    _search_decode_attn_block)


def best_decode_attn_block(
    batch: int, kvh: int, group: int, s: int, d: int, *,
    measure: Optional[Callable[[int], float]] = None,
) -> DecodeAttnCandidate:
    """block_s pick for one decode-attention shape class.

    ``measure=None`` (the dispatch default, what `ops.decode_attention`
    uses) ranks with the cache-bytes roofline and is cached per shape
    class. On real TPU, pass ``measure`` (block_s -> wall-clock) to rank
    candidates empirically — wall-clock autotune parity with the GEMM
    `auto_tune`; measured searches are not cached (the callable's timings
    are the caller's to memoize).
    """
    if measure is None:
        return _best_decode_attn_block_modeled(batch, kvh, group, s, d)
    return _search_decode_attn_block(batch, kvh, group, s, d, measure)


# ---------------------------------------------------------------------------
# chunked-prefill attention shape class (block-S for kernels/chunk_attn.py)
# ---------------------------------------------------------------------------


def chunk_attn_cost(batch: int, kvh: int, group: int, chunk: int, s: int,
                    d: int, *, block_s: int, start: int = 0) -> dict:
    """Roofline cost of one C-token chunk-attention call at one S-tile size.

    The chunk attends the prefix ``[0, start + chunk)``; the kernel fetches
    whole S-blocks, so the streamed cache is ``ceil((start+chunk)/block_s)
    * block_s`` positions — O(prefix), not O(S). The naive XLA path this
    replaces streams (and dequantizes) all ``s`` positions regardless of
    ``start``; `benchmarks/bench_prefill_chunk.py` gates that gap. Every
    grid step (skipped or not) pays GRID_STEP_US, same as the decode
    search — what keeps the pick off degenerate tiny tiles at long S.
    """
    rows = batch * kvh
    end = min(start + chunk, s)
    fetched = (max(end, 1) + block_s - 1) // block_s * block_s
    fetched = min(fetched, s)
    pos_bytes = 2 * d + 2 * 4  # int8 k + int8 v + f32 k/v scales per position
    cache_bytes = rows * fetched * pos_bytes
    qo_bytes = rows * chunk * group * d * (4 + 4)  # q read + out write, f32
    total_bytes = cache_bytes + qo_bytes
    ops = 2.0 * rows * fetched * chunk * group * d * 2  # QK + PV int8 BMMs
    t_mem = total_bytes / HBM_BW
    t_cmp = ops / INT8_PEAK
    t_grid = rows * (s // block_s) * GRID_STEP_US * 1e-6
    t = max(t_mem, t_cmp) + t_grid
    # double-buffered k/v tiles + scale rows, plus the resident (C·G)-row
    # q/acc/m/l state (q both f32-in and int8 re-quantized)
    vmem = (2 * (2 * block_s * d + 2 * 4 * block_s)
            + chunk * group * (d * (4 + 4 + 1) + 3 * 4))
    return {"t_us": t * 1e6, "cache_bytes": cache_bytes, "vmem": vmem}


def _search_chunk_attn_block(
    batch: int, kvh: int, group: int, chunk: int, s: int, d: int,
    page: Optional[int] = None,
    measure: Optional[Callable[[int], float]] = None,
) -> DecodeAttnCandidate:
    """block_s search for the chunk-attention kernel.

    Candidates are the kernel-legal tiles: divisors of ``s`` from the
    shared candidate set (contiguous mode), or divisors of ``page`` plus
    the page itself (paged mode — a tile spanning two logical pages would
    straddle two discontiguous physical blocks, same restriction as
    `best_paged_decode_attn_block`). The roofline cost is averaged over
    representative chunk offsets (start 0, S/2, S-C) so the pick balances
    short-prefix tail waste against long-prefix grid overhead. A
    ``measure`` callable (block_s -> time) replaces the modeled ranking;
    legality filtering stays model-side either way.
    """
    if page is None:
        cands = sorted({c for c in _BS_CANDIDATES
                        if c <= s and s % c == 0} | {s})
    else:
        cands = sorted({c for c in _BS_CANDIDATES
                        if c <= page and page % c == 0} | {page})
    best: Optional[DecodeAttnCandidate] = None
    starts = sorted({0, max(s // 2, 0), max(s - chunk, 0)})
    for bs in cands:
        rs = [chunk_attn_cost(batch, kvh, group, chunk, s, d, block_s=bs,
                              start=st) for st in starts]
        if rs[0]["vmem"] > VMEM_BYTES // 4:
            continue
        t = measure(bs) if measure is not None \
            else sum(r["t_us"] for r in rs) / len(rs)
        # starts is sorted ascending: rs[-1] is the longest-prefix cost
        cand = DecodeAttnCandidate(bs, t, rs[-1]["cache_bytes"],
                                   rs[0]["vmem"])
        if best is None or cand.t_us < best.t_us:
            best = cand
    if best is None:
        raise ValueError(
            f"no feasible chunk-attn block for (B={batch},KVH={kvh},"
            f"G={group},C={chunk},S={s},D={d},page={page})")
    return best


_best_chunk_attn_block_modeled = functools.lru_cache(maxsize=4096)(
    _search_chunk_attn_block)


def best_chunk_attn_block(
    batch: int, kvh: int, group: int, chunk: int, s: int, d: int, *,
    page: Optional[int] = None,
    measure: Optional[Callable[[int], float]] = None,
) -> DecodeAttnCandidate:
    """block_s pick for one chunk-attention shape class.

    ``measure=None`` (the dispatch default, what `ops.chunk_attention`
    uses) ranks with the cache-bytes roofline and is cached per shape
    class; pass ``measure`` (block_s -> wall-clock) on real TPU for
    empirical ranking (`auto_tune` parity; measured searches are not
    cached). ``page`` restricts candidates to divisors of the paged
    pool's page size (the paged kernel's legality rule).
    """
    if measure is None:
        return _best_chunk_attn_block_modeled(batch, kvh, group, chunk, s,
                                              d, page)
    return _search_chunk_attn_block(batch, kvh, group, chunk, s, d, page,
                                    measure)


@functools.lru_cache(maxsize=4096)
def best_paged_decode_attn_block(
    batch: int, kvh: int, group: int, s: int, d: int, page: int,
) -> DecodeAttnCandidate:
    """block_s pick for the *paged* decode-attention kernel.

    The paged kernel resolves physical blocks through the block table, so
    its S-tile must subdivide one ``page`` (``block_s | page``) — a tile
    spanning two logical pages would straddle two discontiguous physical
    blocks. Candidates are therefore the kernel-legal divisors of the page
    size (plus the page itself, always legal); ranking, the
    representative valid-length mix, and the VMEM admission rule are the
    shared `_search_decode_attn_block` machinery. In practice the engine
    picks pages >= the roofline's preferred tile, so this degenerates to
    ``block_s == page`` except for very large pages.
    """
    cands = tuple(sorted({c for c in _BS_CANDIDATES
                          if c <= page and page % c == 0} | {page}))
    return _search_decode_attn_block(batch, kvh, group, s, d, cands=cands)


@functools.lru_cache(maxsize=4096)
def best_blocks(m: int, k: int, n: int, w_bits: int) -> KernelCandidate:
    """Cached kernel-legal block config for one GEMM shape.

    The dispatch cache: every distinct (M, K, N, w_bits) the serving path
    encounters is searched once per process, then the jit cache takes over
    (block sizes are static args of the Pallas call). Prefill and decode
    have different M and therefore get independently-chosen tiles.

    ``k`` must already be the 32-padded contraction length (``pw.planes``
    geometry), so divisibility is checked against the real kernel operand.
    """
    return auto_tune(m, k, n, w_bits=w_bits, require_divisible=True)

"""Auto Kernel Search for the ABQ GEMM (the paper's Appendix D, TPU form).

On GPU the paper benchmarks candidate (BM, BN, BK, warp layout) tiles and
keeps the fastest. Without wall-clock on this container, the TPU version
ranks candidates with the v5e roofline cost model (HBM stream vs MXU time,
double-buffered) under the VMEM budget; on real TPU the same search loop
plugs a wall-clock ``measure`` callable in place of the model.

Used by `benchmarks/bench_kernel_ablation.py` (Table 4 analogue) and
available to `abq_matmul_pallas` callers for block selection.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

HBM_BW = 819e9
INT8_PEAK = 394e12
VMEM_BYTES = 128 * 2**20


@dataclasses.dataclass(frozen=True)
class KernelCandidate:
    block_m: int
    block_n: int
    block_k: int
    t_us: float
    hbm_bytes: float
    vmem_bytes: float


def model_cost(m: int, k: int, n: int, *, w_bits: int, packed: bool = True,
               overlap: bool = True, bm: int = 128, bn: int = 128,
               bk: int = 512) -> dict:
    """HBM traffic + MXU time for one tiled bit-plane GEMM invocation."""
    m_eff = max(m, 8)
    planes = w_bits if packed else 8
    passes = max(m_eff // bm, 1)  # weight tiles re-streamed per M pass
    w_bytes = passes * (planes * k * n / 8)
    a_bytes = (n // max(bn, 1)) * (m_eff * k)  # act tile re-read per N block
    o_bytes = 2 * m_eff * n
    total_bytes = w_bytes + a_bytes + o_bytes
    ops = 2.0 * m_eff * k * n * planes
    t_mem = total_bytes / HBM_BW
    t_cmp = ops / INT8_PEAK
    t = max(t_mem, t_cmp) if overlap else t_mem + t_cmp
    vmem = bm * bk + bk * bn + 4 * bm * bn + planes * bk * bn / 8
    return {"t_us": t * 1e6, "bytes": total_bytes, "vmem": vmem}


def auto_tune(
    m: int,
    k: int,
    n: int,
    *,
    w_bits: int,
    measure: Optional[Callable[[int, int, int], float]] = None,
    vmem_budget: int = VMEM_BYTES // 4,  # double-buffering headroom
) -> KernelCandidate:
    """Pick (BM, BN, BK) minimizing modeled (or measured) time."""
    best: Optional[KernelCandidate] = None
    for bm, bn, bk in itertools.product(
        (8, 16, 32, 64, 128, 256), (128, 256, 512), (128, 256, 512, 1024, 2048)
    ):
        if bk > k or bn > n or bk % 32:
            continue
        r = model_cost(m, k, n, w_bits=w_bits, bm=bm, bn=bn, bk=bk)
        if r["vmem"] > vmem_budget:
            continue
        t = measure(bm, bn, bk) if measure is not None else r["t_us"]
        cand = KernelCandidate(bm, bn, bk, t, r["bytes"], r["vmem"])
        if best is None or cand.t_us < best.t_us:
            best = cand
    if best is None:
        raise ValueError(f"no feasible block config for ({m},{k},{n})")
    return best

"""Causal GQA flash attention (prefill) as a Pallas TPU kernel.

Standard online-softmax tiling (FlashAttention-2 schedule) adapted to the TPU
memory hierarchy: q/k/v tiles stream HBM→VMEM per BlockSpec, the running
(max, sum, acc) state lives in VMEM scratch across the KV sweep, and the MXU
sees 128-aligned (BQ×D)·(D×BK) and (BQ×BK)·(BK×D) matmuls.

GQA is handled in the index maps: query-head h reads kv-head h // group_size,
so no materialized `jnp.repeat` of K/V (that repeat is pure HBM waste — it is
one of the things this kernel exists to delete).

Causality prunes whole KV blocks: for q block i, kv blocks with
start > q_end are skipped via `pl.when` (they contribute nothing), which
halves the work for long prefill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params

Array = jax.Array

_NEG_INF = -1e30

_CompilerParams = tpu_compiler_params()


def _flash_kernel(
    q_ref,  # (1, BQ, D)
    k_ref,  # (1, BK, D)
    v_ref,  # (1, BK, D)
    o_ref,  # (1, BQ, D)
    m_ref,  # VMEM (BQ, 1) f32
    l_ref,  # VMEM (BQ, 1) f32
    acc_ref,  # VMEM (BQ, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_steps: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, BK)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "scale",
        "block_q",
        "block_k",
        "q_offset",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    q_offset: int = 0,
    interpret: bool = False,
) -> Array:
    """q: [B, Sq, H, D]; k/v: [B, Skv, KVH, D] -> [B, Sq, H, D].

    H % KVH == 0 (GQA). Sq % block_q == 0 and Skv % block_k == 0 are required
    (the ops wrapper pads); D should be 128-aligned for the MXU.
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    if scale is None:
        scale = 1.0 / (d**0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq lens ({sq},{skv}) must tile by ({block_q},{block_k})")
    kv_steps = skv // block_k

    # layout: fold heads into the batch grid axis; keep (seq, d) as the tile
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    grid = (b * h, sq // block_q, kv_steps)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            kv_steps=kv_steps,
            q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

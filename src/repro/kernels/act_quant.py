"""Fused per-token activation quantization ("ReQuant") Pallas kernel.

The paper fuses online activation quantization into adjacent operators
(§3.4 "Engine Implementation", Fig. 4b). On TPU the equivalent is a rowwise
VPU kernel: absmax → scale → round → int8, one pass over the row in VMEM,
so the bf16 activation never round-trips HBM between the producer op and
the quantized GEMM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import requant_rows

Array = jax.Array


def _act_quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    q, scale = requant_rows(x_ref[...], qmax)
    q_ref[...] = q
    s_ref[...] = scale


@functools.partial(
    jax.jit, static_argnames=("block_m", "qmax", "interpret")
)
def act_quant_pallas(
    x: Array,
    *,
    block_m: int = 256,
    qmax: float = 127.0,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """x bf16/f32 [M, D] -> (int8 [M, D], f32 [M, 1]) per-token symmetric."""
    m, d = x.shape
    block_m = min(block_m, m)
    pm = (m + block_m - 1) // block_m * block_m
    if pm != m:
        x = jnp.pad(x, ((0, pm - m), (0, 0)))
    grid = (pm // block_m,)
    q, s = pl.pallas_call(
        functools.partial(_act_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pm, d), jnp.int8),
            jax.ShapeDtypeStruct((pm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:m], s[:m]
